//! The Zoe state store (§5): application records modeled as a simple
//! state machine, with JSON persistence (the paper uses PostgreSQL; an
//! embedded JSON-file store preserves the same interface and semantics).

use std::collections::{BTreeMap, VecDeque};
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::backend::ContainerId;
use crate::util::json::Json;

use super::app::AppDescription;

/// Application life-cycle (§5's "simple state-machine").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AppState {
    /// Received, not yet validated into the queue.
    Submitted,
    /// Waiting in the pending queue.
    Queued,
    /// Admitted; containers being created.
    Starting,
    /// Core components running.
    Running,
    /// Completed its work.
    Finished,
    /// Terminated by a client request.
    Killed,
    /// Terminated by an error.
    Failed,
}

impl AppState {
    /// Lowercase wire/state-store name.
    pub fn label(&self) -> &'static str {
        match self {
            AppState::Submitted => "submitted",
            AppState::Queued => "queued",
            AppState::Starting => "starting",
            AppState::Running => "running",
            AppState::Finished => "finished",
            AppState::Killed => "killed",
            AppState::Failed => "failed",
        }
    }

    /// Inverse of [`AppState::label`].
    pub fn parse(s: &str) -> Option<AppState> {
        Some(match s {
            "submitted" => AppState::Submitted,
            "queued" => AppState::Queued,
            "starting" => AppState::Starting,
            "running" => AppState::Running,
            "finished" => AppState::Finished,
            "killed" => AppState::Killed,
            "failed" => AppState::Failed,
            _ => return None,
        })
    }

    /// Terminal states (no transition leaves them; these are the
    /// records store retention may evict).
    pub fn is_terminal(self) -> bool {
        matches!(self, AppState::Finished | AppState::Killed | AppState::Failed)
    }

    /// Legal transitions of the state machine. `Running → Queued` is the
    /// wholesale-preemption path (a [`crate::sched::Decision::Preempt`]
    /// from a custom scheduler core re-queues the application).
    pub fn can_transition(self, to: AppState) -> bool {
        use AppState::*;
        matches!(
            (self, to),
            (Submitted, Queued)
                | (Queued, Starting)
                | (Starting, Running)
                | (Running, Finished)
                | (Running, Queued)
                | (Queued, Killed)
                | (Starting, Killed)
                | (Running, Killed)
                // Queued → Failed: admission control refused the app
                // (deadline infeasible under `slo@reject:`) before it
                // ever started.
                | (Queued, Failed)
                | (Starting, Failed)
                | (Running, Failed)
        )
    }
}

/// One application's record.
#[derive(Clone, Debug)]
pub struct AppRecord {
    /// Store-assigned application id.
    pub id: u32,
    /// The submitted description.
    pub desc: AppDescription,
    /// Current state-machine state.
    pub state: AppState,
    /// Submission time (master clock, seconds).
    pub submitted_at: f64,
    /// Time it entered `Running` (NaN before).
    pub started_at: f64,
    /// Time it reached a terminal state (NaN before).
    pub finished_at: f64,
    /// Containers ever created for it.
    pub containers: Vec<ContainerId>,
}

impl AppRecord {
    /// Completion − submission, once `Finished`.
    pub fn turnaround(&self) -> Option<f64> {
        if self.state == AppState::Finished {
            Some(self.finished_at - self.submitted_at)
        } else {
            None
        }
    }

    /// Start − submission, once started.
    pub fn queuing(&self) -> Option<f64> {
        if self.started_at.is_nan() {
            None
        } else {
            Some(self.started_at - self.submitted_at)
        }
    }
}

/// The store: in-memory map + JSON file persistence.
///
/// # Retention
///
/// By default every record is kept forever (the §5 PostgreSQL-like
/// behavior). A long-lived master serving a continuous stream of
/// applications wants bounded memory instead:
/// [`StateStore::set_retention`] keeps only the most recent `n`
/// *terminal* records (Finished/Killed/Failed) — active records
/// (Submitted/Queued/Starting/Running) are never evicted — so store
/// memory is O(active + retained). Evictions are counted
/// ([`StateStore::evicted`]) and a `status`/`list` query for an evicted
/// id simply misses, like any unknown id.
#[derive(Debug, Default)]
pub struct StateStore {
    apps: BTreeMap<u32, AppRecord>,
    next_id: u32,
    /// Keep at most this many terminal records (`None` = keep all).
    retain_done: Option<usize>,
    /// Terminal record ids in the order they became terminal (eviction
    /// order: oldest first).
    terminal_order: VecDeque<u32>,
    /// Terminal records evicted so far.
    evicted: u64,
}

impl StateStore {
    /// An empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Bound the number of retained terminal records (see the type-level
    /// docs); `None` restores keep-everything. Applies retroactively to
    /// already-terminal records.
    pub fn set_retention(&mut self, retain_done: Option<usize>) {
        self.retain_done = retain_done;
        self.apply_retention();
    }

    /// The current retention bound (`None` = unbounded).
    pub fn retention(&self) -> Option<usize> {
        self.retain_done
    }

    /// How many terminal records retention has evicted so far.
    pub fn evicted(&self) -> u64 {
        self.evicted
    }

    fn apply_retention(&mut self) {
        let Some(keep) = self.retain_done else { return };
        while self.terminal_order.len() > keep {
            let id = self.terminal_order.pop_front().expect("non-empty");
            self.apps.remove(&id);
            self.evicted += 1;
        }
    }

    /// Insert a submission at time `now`; returns the assigned id.
    pub fn insert(&mut self, desc: AppDescription, now: f64) -> u32 {
        let id = self.next_id;
        self.next_id += 1;
        self.apps.insert(
            id,
            AppRecord {
                id,
                desc,
                state: AppState::Submitted,
                submitted_at: now,
                started_at: f64::NAN,
                finished_at: f64::NAN,
                containers: Vec::new(),
            },
        );
        id
    }

    /// Look up a record.
    pub fn get(&self, id: u32) -> Option<&AppRecord> {
        self.apps.get(&id)
    }

    /// Mutable record access.
    pub fn get_mut(&mut self, id: u32) -> Option<&mut AppRecord> {
        self.apps.get_mut(&id)
    }

    /// Apply a state transition, stamping the relevant timestamp;
    /// illegal transitions error.
    pub fn transition(&mut self, id: u32, to: AppState, now: f64) -> Result<()> {
        let rec = self
            .apps
            .get_mut(&id)
            .ok_or_else(|| anyhow!("no such app {id}"))?;
        if !rec.state.can_transition(to) {
            return Err(anyhow!(
                "illegal transition {} -> {} for app {id}",
                rec.state.label(),
                to.label()
            ));
        }
        match to {
            AppState::Running => rec.started_at = now,
            AppState::Finished | AppState::Killed | AppState::Failed => rec.finished_at = now,
            _ => {}
        }
        rec.state = to;
        if to.is_terminal() {
            // Terminal states never transition out, so an id enters this
            // queue at most once.
            self.terminal_order.push_back(id);
            self.apply_retention();
        }
        Ok(())
    }

    /// All records, by ascending id.
    pub fn iter(&self) -> impl Iterator<Item = &AppRecord> {
        self.apps.values()
    }

    /// Number of records currently in `state`.
    pub fn count_in(&self, state: AppState) -> usize {
        self.apps.values().filter(|a| a.state == state).count()
    }

    // ---- persistence ------------------------------------------------------

    /// Serialize every record (the persistence format).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.apps
                .values()
                .map(|a| {
                    Json::obj(vec![
                        ("id", Json::num(a.id as f64)),
                        ("state", Json::str(a.state.label())),
                        ("submitted_at", Json::num(a.submitted_at)),
                        (
                            "started_at",
                            if a.started_at.is_nan() {
                                Json::Null
                            } else {
                                Json::num(a.started_at)
                            },
                        ),
                        (
                            "finished_at",
                            if a.finished_at.is_nan() {
                                Json::Null
                            } else {
                                Json::num(a.finished_at)
                            },
                        ),
                        ("desc", a.desc.to_json()),
                    ])
                })
                .collect(),
        )
    }

    /// Write the store to a JSON file.
    pub fn dump(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    /// Load a store dumped by [`StateStore::dump`] (container lists are
    /// not persisted).
    pub fn load(path: impl AsRef<Path>) -> Result<StateStore> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        let mut store = StateStore::new();
        for aj in j.as_arr().ok_or_else(|| anyhow!("expected array"))? {
            let id = aj.get("id").as_u64().ok_or_else(|| anyhow!("bad id"))? as u32;
            let desc = AppDescription::from_json(aj.get("desc"))?;
            let rec = AppRecord {
                id,
                desc,
                state: AppState::parse(aj.get("state").as_str().unwrap_or(""))
                    .ok_or_else(|| anyhow!("bad state"))?,
                submitted_at: aj.get("submitted_at").as_f64().unwrap_or(f64::NAN),
                started_at: aj.get("started_at").as_f64().unwrap_or(f64::NAN),
                finished_at: aj.get("finished_at").as_f64().unwrap_or(f64::NAN),
                containers: Vec::new(),
            };
            store.next_id = store.next_id.max(id + 1);
            if rec.state.is_terminal() {
                store.terminal_order.push_back(id);
            }
            store.apps.insert(id, rec);
        }
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoe::templates;

    #[test]
    fn state_machine_legality() {
        use AppState::*;
        assert!(Submitted.can_transition(Queued));
        assert!(Queued.can_transition(Starting));
        assert!(Starting.can_transition(Running));
        assert!(Running.can_transition(Finished));
        assert!(!Submitted.can_transition(Running));
        assert!(!Finished.can_transition(Running));
        assert!(!Queued.can_transition(Finished));
    }

    #[test]
    fn transitions_update_timestamps() {
        let mut s = StateStore::new();
        let id = s.insert(templates::tf_single(), 10.0);
        s.transition(id, AppState::Queued, 10.0).unwrap();
        s.transition(id, AppState::Starting, 12.0).unwrap();
        s.transition(id, AppState::Running, 13.0).unwrap();
        s.transition(id, AppState::Finished, 99.0).unwrap();
        let rec = s.get(id).unwrap();
        assert_eq!(rec.turnaround(), Some(89.0));
        assert_eq!(rec.queuing(), Some(3.0));
        assert!(s.transition(id, AppState::Running, 100.0).is_err());
    }

    #[test]
    fn retention_evicts_oldest_terminal_records_only() {
        let mut s = StateStore::new();
        s.set_retention(Some(2));
        let mut terminal = Vec::new();
        for i in 0..5 {
            let id = s.insert(templates::tf_single(), i as f64);
            s.transition(id, AppState::Queued, i as f64).unwrap();
            s.transition(id, AppState::Starting, i as f64).unwrap();
            s.transition(id, AppState::Running, i as f64).unwrap();
            s.transition(id, AppState::Finished, 10.0 + i as f64).unwrap();
            terminal.push(id);
        }
        // Only the 2 most recent terminal records remain.
        assert_eq!(s.evicted(), 3);
        assert!(s.get(terminal[0]).is_none());
        assert!(s.get(terminal[2]).is_none());
        assert!(s.get(terminal[3]).is_some());
        assert!(s.get(terminal[4]).is_some());
        // Active records are never evicted, however many there are.
        let live: Vec<u32> = (0..4)
            .map(|i| {
                let id = s.insert(templates::tf_single(), 20.0 + i as f64);
                s.transition(id, AppState::Queued, 20.0).unwrap();
                id
            })
            .collect();
        assert!(live.iter().all(|&id| s.get(id).is_some()));
        assert_eq!(s.count_in(AppState::Queued), 4);
        // Ids keep monotonically increasing across evictions (public app
        // ids are never recycled — only internal slab slots are).
        assert!(live[0] > terminal[4]);
        // Tightening retention retroactively evicts.
        s.set_retention(Some(0));
        assert!(s.get(terminal[4]).is_none());
        assert_eq!(s.evicted(), 5);
    }

    #[test]
    fn persistence_roundtrip() {
        let mut s = StateStore::new();
        let a = s.insert(templates::spark_als(16), 1.0);
        let b = s.insert(templates::tf_distributed(), 2.0);
        s.transition(a, AppState::Queued, 1.0).unwrap();
        let dir = std::env::temp_dir().join("zoe_state_test.json");
        s.dump(&dir).unwrap();
        let loaded = StateStore::load(&dir).unwrap();
        assert_eq!(loaded.get(a).unwrap().desc, templates::spark_als(16));
        assert_eq!(loaded.get(b).unwrap().desc, templates::tf_distributed());
        assert_eq!(loaded.get(a).unwrap().state, AppState::Queued);
        let _ = std::fs::remove_file(dir);
    }
}

//! The Zoe client API (§5): a TCP JSON-lines protocol with a threaded
//! server and a matching client. Mutating calls (submit, kill) and
//! monitoring calls (status, list, stats) — the same surface Zoe's REST
//! API exposes, minus HTTP framing (std-only build).
//!
//! Wire format: one JSON object per line.
//!   → {"op":"submit","app":{...}}     ← {"ok":true,"id":7}
//!   → {"op":"status","id":7}          ← {"ok":true,"state":"running",...}
//!   → {"op":"list"}                   ← {"ok":true,"apps":[...]}
//!   → {"op":"stats"}                  ← {"ok":true,...}
//!   → {"op":"kill","id":7}            ← {"ok":true}

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::util::json::Json;

use super::app::AppDescription;
use super::master::ZoeMaster;

/// Handle one API request against the master.
fn handle_request(master: &Mutex<ZoeMaster>, req: &Json) -> Json {
    let op = req.get("op").as_str().unwrap_or("");
    let mut m = master.lock().unwrap();
    match op {
        "submit" => match AppDescription::from_json(req.get("app")) {
            Ok(desc) => match m.submit(desc) {
                Ok(id) => Json::obj(vec![("ok", Json::Bool(true)), ("id", Json::num(id as f64))]),
                Err(e) => err_json(&e.to_string()),
            },
            Err(e) => err_json(&format!("bad app description: {e}")),
        },
        "status" => {
            let Some(id) = req.get("id").as_u64() else {
                return err_json("missing id");
            };
            match m.store.get(id as u32) {
                Some(rec) => Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("id", Json::num(rec.id as f64)),
                    ("name", Json::str(&rec.desc.name)),
                    ("state", Json::str(rec.state.label())),
                    (
                        "turnaround",
                        rec.turnaround().map(Json::num).unwrap_or(Json::Null),
                    ),
                    ("queuing", rec.queuing().map(Json::num).unwrap_or(Json::Null)),
                ]),
                None => err_json("no such app"),
            }
        }
        "list" => {
            let apps: Vec<Json> = m
                .store
                .iter()
                .map(|rec| {
                    Json::obj(vec![
                        ("id", Json::num(rec.id as f64)),
                        ("name", Json::str(&rec.desc.name)),
                        ("state", Json::str(rec.state.label())),
                    ])
                })
                .collect();
            Json::obj(vec![("ok", Json::Bool(true)), ("apps", Json::Arr(apps))])
        }
        "stats" => {
            let used = m.backend.used();
            let total = m.backend.total();
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("pending", Json::num(m.pending_len() as f64)),
                ("running", Json::num(m.serving_len() as f64)),
                ("cpu_used", Json::num(used.cpu)),
                ("cpu_total", Json::num(total.cpu)),
                ("ram_used_mb", Json::num(used.ram_mb)),
                ("ram_total_mb", Json::num(total.ram_mb)),
            ])
        }
        "kill" => {
            let Some(id) = req.get("id").as_u64() else {
                return err_json("missing id");
            };
            match m.kill(id as u32) {
                Ok(()) => Json::obj(vec![("ok", Json::Bool(true))]),
                Err(e) => err_json(&e.to_string()),
            }
        }
        other => err_json(&format!("unknown op '{other}'")),
    }
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::str(msg))])
}

/// Per-connection read timeout. A client that connects and then sends
/// nothing (or half a line) would otherwise pin its server thread in
/// `read_line` forever; after this long with no traffic the connection
/// is dropped. `ZOE_API_IDLE_TIMEOUT_MS` overrides the 30 s default
/// (tests use a few hundred ms).
fn idle_timeout() -> std::time::Duration {
    let ms = std::env::var("ZOE_API_IDLE_TIMEOUT_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .filter(|&v| v > 0)
        .unwrap_or(30_000);
    std::time::Duration::from_millis(ms)
}

/// True when an I/O error is a read-timeout expiring rather than a real
/// transport failure (`WouldBlock` on unix, `TimedOut` on windows).
fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// The API server: listens on `addr`, one thread per connection.
pub struct ApiServer {
    /// The address actually bound (resolves port 0).
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl ApiServer {
    /// Bind and serve in background threads. Pass port 0 for an ephemeral
    /// port (tests).
    pub fn spawn(master: Arc<Mutex<ZoeMaster>>, bind: &str) -> Result<ApiServer> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let master = Arc::clone(&master);
                        std::thread::spawn(move || {
                            let _ = serve_conn(master, stream);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(ApiServer {
            addr,
            stop,
            handle: Some(handle),
        })
    }

    /// Stop accepting and join the accept thread.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ApiServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn serve_conn(master: Arc<Mutex<ZoeMaster>>, stream: TcpStream) -> Result<()> {
    stream.set_read_timeout(Some(idle_timeout()))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {}
            Err(e) if is_timeout(&e) => return Ok(()), // idle client: drop it
            Err(e) => return Err(e.into()),
        }
        let resp = match Json::parse(line.trim()) {
            Ok(req) => handle_request(&master, &req),
            Err(e) => err_json(&format!("bad json: {e}")),
        };
        stream.write_all(resp.to_string().as_bytes())?;
        stream.write_all(b"\n")?;
    }
}

/// A blocking API client.
pub struct ApiClient {
    reader: BufReader<TcpStream>,
    stream: TcpStream,
}

impl ApiClient {
    /// Connect to a master's API server. Responses are waited on for at
    /// most the `ZOE_API_IDLE_TIMEOUT_MS` read timeout (default 30 s),
    /// so a wedged server surfaces as an error instead of a hang.
    pub fn connect(addr: &str) -> Result<ApiClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(idle_timeout()))?;
        Ok(ApiClient {
            reader: BufReader::new(stream.try_clone()?),
            stream,
        })
    }

    /// Send one request object and read one response line.
    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.stream.write_all(req.to_string().as_bytes())?;
        self.stream.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let resp = Json::parse(line.trim()).map_err(|e| anyhow!("bad response: {e}"))?;
        Ok(resp)
    }

    /// Submit an application; returns the assigned id.
    pub fn submit(&mut self, desc: &AppDescription) -> Result<u32> {
        let resp = self.call(&Json::obj(vec![
            ("op", Json::str("submit")),
            ("app", desc.to_json()),
        ]))?;
        if resp.get("ok").as_bool() != Some(true) {
            return Err(anyhow!(
                "submit failed: {}",
                resp.get("error").as_str().unwrap_or("?")
            ));
        }
        Ok(resp.get("id").as_u64().unwrap_or(0) as u32)
    }

    /// Fetch one application's status object.
    pub fn status(&mut self, id: u32) -> Result<Json> {
        self.call(&Json::obj(vec![
            ("op", Json::str("status")),
            ("id", Json::num(id as f64)),
        ]))
    }

    /// Fetch cluster-wide stats.
    pub fn stats(&mut self) -> Result<Json> {
        self.call(&Json::obj(vec![("op", Json::str("stats"))]))
    }

    /// Ask the master to kill an application.
    pub fn kill(&mut self, id: u32) -> Result<Json> {
        self.call(&Json::obj(vec![
            ("op", Json::str("kill")),
            ("id", Json::num(id as f64)),
        ]))
    }
}

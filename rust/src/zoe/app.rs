//! The Zoe configuration language (§5): JSON application descriptions —
//! frameworks, components with classes (core/elastic), resource
//! reservations, and the "command line" attribute carrying the work spec.

use anyhow::{anyhow, bail, Result};

use crate::core::{AppClass, ComponentClass, ReqId, Request, Resources};
use crate::runtime::WorkKind;
use crate::util::json::Json;

/// One component group (homogeneous replicas of a framework component).
#[derive(Clone, Debug, PartialEq)]
pub struct ComponentDef {
    /// Component-group name (e.g. "worker").
    pub name: String,
    /// Core or elastic (§2.1).
    pub class: ComponentClass,
    /// Number of replicas in the group.
    pub count: u32,
    /// Per-replica CPU cores.
    pub cpu: f64,
    /// Per-replica RAM, MB.
    pub ram_mb: f64,
    /// Docker image name (descriptive in this substrate).
    pub image: String,
    /// Does this component execute analytic work steps? (Workers do;
    /// pure-service components — clients, masters, parameter servers,
    /// notebooks — hold resources but do not claim steps.)
    pub worker: bool,
}

impl ComponentDef {
    /// Per-replica resource vector.
    pub fn res(&self) -> Resources {
        Resources::new(self.cpu, self.ram_mb)
    }
}

/// A Zoe application description.
#[derive(Clone, Debug, PartialEq)]
pub struct AppDescription {
    /// Application name.
    pub name: String,
    /// The "command line" attribute: selects the analytic program.
    pub command: String,
    /// Parsed work kind (from the command) + step budget.
    pub work: WorkKind,
    /// Total work steps the application must execute.
    pub work_steps: u64,
    /// External priority (higher = more urgent).
    pub priority: f64,
    /// Completion deadline relative to submission, seconds
    /// (`f64::INFINITY` = none). Consumed by the deadline-aware policies
    /// (EDF/LLF) and the `slo:` wrapper's admission control; plain
    /// schedulers ignore it.
    pub deadline: f64,
    /// Human-in-the-loop session (gets priority in §6 experiments).
    pub interactive: bool,
    /// The component groups.
    pub components: Vec<ComponentDef>,
    /// Environment passed to components (host names are filled by the
    /// service-discovery layer at start time).
    pub env: Vec<(String, String)>,
}

impl AppDescription {
    /// Total core/elastic component counts and per-component resources.
    /// (Zoe treats component groups individually; the scheduler view
    /// aggregates per class with a weighted-max resource envelope.)
    pub fn core_components(&self) -> impl Iterator<Item = &ComponentDef> {
        self.components
            .iter()
            .filter(|c| c.class == ComponentClass::Core)
    }

    /// The elastic component groups.
    pub fn elastic_components(&self) -> impl Iterator<Item = &ComponentDef> {
        self.components
            .iter()
            .filter(|c| c.class == ComponentClass::Elastic)
    }

    /// Total core replicas across groups.
    pub fn n_core(&self) -> u32 {
        self.core_components().map(|c| c.count).sum()
    }

    /// Total elastic replicas across groups.
    pub fn n_elastic(&self) -> u32 {
        self.elastic_components().map(|c| c.count).sum()
    }

    /// The scheduler-core view of this application (§2.2): per-class
    /// component counts with a componentwise-**max** ("envelope")
    /// per-component resource vector — conservative, so a virtual
    /// placement of `n` envelope components always physically fits the
    /// `n` actual (possibly smaller) components on the same nodes — plus
    /// a runtime estimate derived from the work-step budget
    /// (`work_steps / (C + E)`, the §2.2 work model solved for T with
    /// one step ≈ one component-second).
    ///
    /// The envelope deliberately trades admission capacity for placement
    /// soundness on heterogeneous applications: an app mixing 1-CPU and
    /// 6-CPU core components is scheduled as if every core were 6 CPUs,
    /// so the master admits somewhat fewer concurrent apps than a
    /// per-component packer would, but an admission decision can never
    /// be physically unplaceable on the hinted nodes. Uniform-component
    /// apps (the sim↔master agreement scenarios) are unaffected.
    pub fn scheduler_request(&self, arrival: f64) -> Request {
        let envelope = |class: ComponentClass| {
            let mut r = Resources::ZERO;
            for c in self.components.iter().filter(|c| c.class == class) {
                r.cpu = r.cpu.max(c.cpu);
                r.ram_mb = r.ram_mb.max(c.ram_mb);
            }
            r
        };
        let n_core = self.n_core();
        let n_elastic = self.n_elastic();
        let class = if self.interactive {
            AppClass::Interactive
        } else if n_elastic == 0 {
            AppClass::BatchRigid
        } else {
            AppClass::BatchElastic
        };
        Request {
            // Placeholder: the executor's request table assigns the real
            // generational handle at allocation.
            id: ReqId::from(0),
            class,
            arrival,
            runtime: (self.work_steps as f64 / (n_core + n_elastic).max(1) as f64).max(1e-6),
            n_core,
            core_res: envelope(ComponentClass::Core),
            n_elastic,
            elastic_res: envelope(ComponentClass::Elastic),
            priority: self.priority,
            deadline: self.deadline,
        }
    }

    /// Check the structural invariants Zoe enforces at submission.
    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty() {
            bail!("application name must not be empty");
        }
        if self.n_core() == 0 {
            bail!("application '{}' needs at least one core component", self.name);
        }
        for c in &self.components {
            if c.count == 0 {
                bail!("component '{}' has count 0", c.name);
            }
            if c.cpu <= 0.0 || c.ram_mb <= 0.0 {
                bail!("component '{}' has non-positive resources", c.name);
            }
        }
        if self.work_steps == 0 {
            bail!("work_steps must be positive");
        }
        if self.deadline.is_finite() && self.deadline <= 0.0 || self.deadline.is_nan() {
            bail!("deadline must be positive (or omitted for none)");
        }
        Ok(())
    }

    // ---- JSON CL ----------------------------------------------------------

    /// Serialize to the Zoe configuration-language JSON. A deadline is
    /// emitted only when finite — its absence *is* the "no deadline"
    /// encoding (JSON has no infinity).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::str(&self.name)),
            ("command", Json::str(&self.command)),
            ("work_steps", Json::num(self.work_steps as f64)),
            ("priority", Json::num(self.priority)),
            ("interactive", Json::Bool(self.interactive)),
        ];
        if self.deadline.is_finite() {
            fields.push(("deadline", Json::num(self.deadline)));
        }
        fields.extend(vec![
            (
                "components",
                Json::Arr(
                    self.components
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("name", Json::str(&c.name)),
                                (
                                    "class",
                                    Json::str(match c.class {
                                        ComponentClass::Core => "core",
                                        ComponentClass::Elastic => "elastic",
                                    }),
                                ),
                                ("count", Json::num(c.count as f64)),
                                ("cpu", Json::num(c.cpu)),
                                ("ram_mb", Json::num(c.ram_mb)),
                                ("image", Json::str(&c.image)),
                                ("worker", Json::Bool(c.worker)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "env",
                Json::Arr(
                    self.env
                        .iter()
                        .map(|(k, v)| Json::Arr(vec![Json::str(k), Json::str(v)]))
                        .collect(),
                ),
            ),
        ]);
        Json::obj(fields)
    }

    /// Parse a configuration-language JSON description.
    pub fn from_json(j: &Json) -> Result<AppDescription> {
        let name = j
            .get("name")
            .as_str()
            .ok_or_else(|| anyhow!("missing 'name'"))?
            .to_string();
        let command = j
            .get("command")
            .as_str()
            .ok_or_else(|| anyhow!("missing 'command'"))?
            .to_string();
        // The first token of the command selects the analytic program —
        // Zoe's "minimal knowledge of the frameworks" contract.
        let prog = command.split_whitespace().next().unwrap_or("");
        let work = WorkKind::parse(prog)
            .ok_or_else(|| anyhow!("unknown analytic program '{prog}' in command"))?;
        let mut components = Vec::new();
        for cj in j
            .get("components")
            .as_arr()
            .ok_or_else(|| anyhow!("missing 'components'"))?
        {
            let class = match cj.get("class").as_str() {
                Some("core") => ComponentClass::Core,
                Some("elastic") => ComponentClass::Elastic,
                other => bail!("bad component class {other:?}"),
            };
            components.push(ComponentDef {
                name: cj
                    .get("name")
                    .as_str()
                    .ok_or_else(|| anyhow!("component missing 'name'"))?
                    .to_string(),
                class,
                count: cj.get("count").as_u64().unwrap_or(1) as u32,
                cpu: cj.get("cpu").as_f64().ok_or_else(|| anyhow!("component missing 'cpu'"))?,
                ram_mb: cj
                    .get("ram_mb")
                    .as_f64()
                    .ok_or_else(|| anyhow!("component missing 'ram_mb'"))?,
                image: cj.get("image").as_str().unwrap_or("zoe/generic").to_string(),
                worker: cj.get("worker").as_bool().unwrap_or(class == ComponentClass::Elastic),
            });
        }
        let mut env = Vec::new();
        if let Some(arr) = j.get("env").as_arr() {
            for e in arr {
                if let Some(pair) = e.as_arr() {
                    if pair.len() == 2 {
                        env.push((
                            pair[0].as_str().unwrap_or("").to_string(),
                            pair[1].as_str().unwrap_or("").to_string(),
                        ));
                    }
                }
            }
        }
        let desc = AppDescription {
            name,
            command,
            work,
            work_steps: j.get("work_steps").as_u64().unwrap_or(100),
            priority: j.get("priority").as_f64().unwrap_or(0.0),
            // Absent = no deadline (see `to_json`).
            deadline: j.get("deadline").as_f64().unwrap_or(f64::INFINITY),
            interactive: j.get("interactive").as_bool().unwrap_or(false),
            components,
            env,
        };
        desc.validate()?;
        Ok(desc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoe::templates;

    #[test]
    fn json_roundtrip() {
        let d = templates::spark_als(16);
        let j = d.to_json();
        let back = AppDescription::from_json(&j).unwrap();
        assert_eq!(back, d);
    }

    #[test]
    fn rejects_coreless_app() {
        let mut d = templates::spark_als(8);
        d.components.retain(|c| c.class != ComponentClass::Core);
        assert!(d.validate().is_err());
    }

    #[test]
    fn rejects_unknown_program() {
        let d = templates::spark_als(8);
        let mut j = d.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("command".into(), Json::str("python quantum.py"));
        }
        assert!(AppDescription::from_json(&j).is_err());
    }

    #[test]
    fn deadline_roundtrips_and_validates() {
        let mut d = templates::spark_als(8);
        d.deadline = 120.0;
        let back = AppDescription::from_json(&d.to_json()).unwrap();
        assert_eq!(back, d);
        d.deadline = -1.0;
        assert!(d.validate().is_err());
        // No deadline = key absent from the CL JSON.
        assert!(!templates::spark_als(8).to_json().to_string().contains("deadline"));
    }

    #[test]
    fn counts_by_class() {
        let d = templates::spark_als(16);
        assert_eq!(d.n_core(), 3);
        assert_eq!(d.n_elastic(), 24);
        let d = templates::tf_distributed();
        assert_eq!(d.n_core(), 15); // 5 PS + 10 workers, all core
        assert_eq!(d.n_elastic(), 0);
    }
}

//! The Zoe system (§5): application configuration language, state store,
//! master (a container-level executor of the shared
//! [`crate::sched::SchedulerCore`]), client API, and the §6 application
//! templates.

mod api;
mod app;
mod experiment;
mod master;
mod state;
mod storage;
pub mod templates;

pub use api::*;
pub use app::*;
pub use experiment::*;
pub use master::*;
pub use state::*;
pub use storage::*;
pub use templates::*;

//! The §6 experiment driver: replay a workload trace of real analytic
//! applications against a Zoe generation on the Swarm-like back-end.
//!
//! Containers execute genuine compute (PJRT artifact steps); experiment
//! time is a **virtual clock** advanced as `steps / (rate × active
//! workers)`, so an application's speed scales with its granted
//! containers exactly as on the paper's testbed (each container is a real
//! CPU allocation there; here host compute is serialized through one PJRT
//! client, so wall time cannot scale — the virtual clock restores the
//! testbed semantics while keeping every FLOP real). See DESIGN.md §4.

use std::sync::Arc;
use std::time::Instant;

use crate::backend::{SwarmBackend, WorkPool};
use crate::runtime::PjrtRuntime;
use crate::sched::{SchedKind, SchedSpec};
use crate::util::rng::Rng;
use crate::util::stats::Samples;

use super::app::AppDescription;
use super::master::ZoeMaster;
use super::state::AppState;
use super::templates;

/// One scheduled submission in a replay trace.
pub struct ReplayArrival {
    /// Submission time (virtual seconds).
    pub at: f64,
    /// What to submit.
    pub desc: AppDescription,
    /// Elastic (B-E) or rigid (B-R), for the Fig-33 class split.
    pub elastic: bool,
}

/// The §6 workload: 100 applications, 80 % Spark-like elastic (ALS +
/// regression templates, 16/8 GB variants), 20 % TF-like rigid;
/// inter-arrivals N(60 s, 40 s) divided by `gap_scale`.
pub fn section6_workload(n: u32, seed: u64, gap_scale: f64) -> Vec<ReplayArrival> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let mut out = Vec::new();
    for _ in 0..n {
        t += rng.normal(60.0, 40.0).clamp(1.0, 180.0) / gap_scale;
        let elastic = rng.chance(0.8);
        let desc = if elastic {
            match rng.below(4) {
                0 => templates::spark_als(16),
                1 => templates::spark_als(8),
                2 => templates::spark_regression(16),
                _ => templates::spark_regression(8),
            }
        } else if rng.chance(0.5) {
            templates::tf_single()
        } else {
            templates::tf_distributed()
        };
        out.push(ReplayArrival { at: t, desc, elastic });
    }
    out
}

/// Metrics of one replayed generation.
pub struct ReplayResult {
    /// Generation label for reports.
    pub label: String,
    /// Turnarounds of elastic (B-E) applications, seconds.
    pub turnaround_be: Samples,
    /// Turnarounds of rigid (B-R) applications, seconds.
    pub turnaround_br: Samples,
    /// Queuing times, seconds.
    pub queuing: Samples,
    /// Sampled CPU allocation fractions.
    pub alloc_cpu: Samples,
    /// Per-container placement+start latency, milliseconds (§6 ramp-up).
    pub rampup_ms: Samples,
    /// Wall-clock seconds spent (host compute).
    pub wall: f64,
    /// Virtual makespan (experiment seconds).
    pub vtime: f64,
    /// PJRT steps actually executed.
    pub steps: u64,
}

/// Replay `arrivals` under the scheduler named by `spec` (any of the
/// four generations or a registered core). `rate` is worker-container
/// steps per virtual second (throughput model); `quanta` is the number of
/// steps the pool executes between scheduler polls.
pub fn replay(
    spec: &SchedSpec,
    arrivals: &[ReplayArrival],
    rt: Arc<PjrtRuntime>,
    quanta: usize,
    rate: f64,
) -> ReplayResult {
    let mut backend = SwarmBackend::paper_testbed();
    backend.set_virtual_clock();
    let mut master = ZoeMaster::new(backend, spec.clone());
    let mut pool = WorkPool::new(rt);
    let wall0 = Instant::now();
    let mut next = 0usize;
    let mut ids: Vec<(u32, bool)> = Vec::new();
    let mut alloc = Samples::new();
    let mut last_sample = -1.0f64;
    let mut total_steps = 0u64;
    loop {
        let v = master.backend.now();
        while next < arrivals.len() && arrivals[next].at <= v {
            match master.submit(arrivals[next].desc.clone()) {
                Ok(id) => ids.push((id, arrivals[next].elastic)),
                Err(e) => log::warn!("submit failed: {e}"),
            }
            next += 1;
        }
        master.handle_events();
        let steps = pool.drive(&mut master.backend, quanta).expect("pjrt step");
        total_steps += steps as u64;
        let active = pool.active_containers().max(1);
        if steps > 0 {
            master.backend.advance(steps as f64 / (rate * active as f64));
        } else if next < arrivals.len() {
            // Idle: jump to the next submission.
            let jump = (arrivals[next].at - v).max(0.0) + 1e-9;
            master.backend.advance(jump);
        } else {
            // Nothing to run and nothing to submit: all done (or stuck).
            let done = ids.iter().all(|&(id, _)| {
                matches!(
                    master.store.get(id).map(|r| r.state),
                    Some(AppState::Finished) | Some(AppState::Killed) | Some(AppState::Failed) | None
                )
            });
            if done {
                break;
            }
            // A finished ledger may still need its completion sweep.
            master.backend.advance(0.01);
            master.handle_events();
        }
        if v - last_sample > 1.0 {
            last_sample = v;
            let used = master.backend.used();
            let total = master.backend.total();
            alloc.push(used.cpu / total.cpu);
        }
        if wall0.elapsed().as_secs_f64() > 1200.0 {
            log::warn!("replay wall cap hit for {}", spec.label());
            break;
        }
    }
    let mut res = ReplayResult {
        // The §6 generation names for the two paper configurations;
        // everything else reports under its spec label.
        label: match spec.kind() {
            Some(SchedKind::Rigid) => "gen-1 (rigid)".to_string(),
            Some(SchedKind::Flexible) => "gen-2 (flexible)".to_string(),
            _ => spec.label().to_string(),
        },
        turnaround_be: Samples::new(),
        turnaround_br: Samples::new(),
        queuing: Samples::new(),
        alloc_cpu: alloc,
        rampup_ms: Samples::new(),
        wall: wall0.elapsed().as_secs_f64(),
        vtime: master.backend.now(),
        steps: total_steps,
    };
    for &(id, elastic) in &ids {
        if let Some(rec) = master.store.get(id) {
            if let Some(ta) = rec.turnaround() {
                if elastic {
                    res.turnaround_be.push(ta);
                } else {
                    res.turnaround_br.push(ta);
                }
            }
            if let Some(q) = rec.queuing() {
                res.queuing.push(q);
            }
        }
    }
    for v in master.placement_latency.values() {
        res.rampup_ms.push(v * 1000.0);
    }
    res
}

//! Cluster resource pool: a set of machines with 2-D capacities
//! (CPU, RAM) on which the schedulers trial-place application components.
//!
//! The schedulers compute *virtual assignments* (§3.2); placement is a
//! greedy first-fit over machines in index order. To keep that greedy
//! scan off the per-event hot path at scale, the pool maintains a
//! **free-capacity index**:
//!
//! * machines are grouped into fixed blocks of [`BLOCK`]; each block
//!   tracks the componentwise **max free** vector of its machines, so a
//!   whole block is skipped in O(1) when no machine in it can fit one
//!   component (exact: the max bounds every machine);
//! * an **open-block cursor** remembers the first block that is not
//!   completely exhausted — greedy fill saturates the low-index prefix,
//!   and the cursor skips it without even touching the block headers;
//! * [`Cluster::can_place_all`] answers all-or-nothing feasibility
//!   without mutating anything (early-exit count), replacing the old
//!   save/place/restore trial dance;
//! * tracked placements can be written into caller-owned, reusable
//!   [`Placement`] buffers (`place_up_to_into` / `place_all_into` /
//!   `place_up_to_append`), so steady-state rebalancing allocates
//!   nothing.
//!
//! Every fast path is semantics-preserving: skipped machines are exactly
//! those whose `fit_count` would be 0, so placements (and therefore
//! simulation results) are identical to a full scan from machine 0.

use crate::core::Resources;

/// Machines per index block (see module docs).
const BLOCK: usize = 16;

/// One machine: total and currently-free resources.
#[derive(Clone, Copy, Debug)]
pub struct Machine {
    /// Installed capacity.
    pub total: Resources,
    /// Currently unallocated capacity.
    pub free: Resources,
}

impl Machine {
    /// An empty machine of capacity `total`.
    pub fn new(total: Resources) -> Self {
        Machine { total, free: total }
    }

    /// How many components of `res` fit in the free space.
    #[inline]
    pub fn fit_count(&self, res: &Resources) -> u32 {
        let by_cpu = if res.cpu > 0.0 {
            ((self.free.cpu + 1e-9) / res.cpu) as u32
        } else {
            u32::MAX
        };
        let by_ram = if res.ram_mb > 0.0 {
            ((self.free.ram_mb + 1e-9) / res.ram_mb) as u32
        } else {
            u32::MAX
        };
        by_cpu.min(by_ram)
    }
}

/// A saved cluster state for trial placements.
#[derive(Clone, Debug)]
pub struct Snapshot {
    free: Vec<Resources>,
    used: Resources,
}

/// What happens to one machine in a capacity-change event.
///
/// Both churn sources — parsed ClusterData2011 `machine_events` rows
/// ([`crate::trace`]) and the synthetic seeded MTBF/MTTR fault model
/// ([`crate::sim`]) — compile down to this one vocabulary, so the
/// engine and the Zoe master apply real and injected churn through the
/// same code path.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ClusterEventKind {
    /// Capacity appears: a brand-new machine (`machine == n_machines()`)
    /// or a failed machine coming back with the given capacity.
    Add(Resources),
    /// The machine dies: its capacity vanishes and every component
    /// placed on it is killed (the schedulers requeue or degrade the
    /// affected applications).
    Remove,
    /// The machine's installed capacity changes in place. When the new
    /// capacity no longer covers what is allocated on the machine, the
    /// executor treats it as a remove + add (components are killed).
    Update(Resources),
}

/// A timestamped capacity change applied to one machine mid-run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClusterEvent {
    /// Simulation time (seconds) at which the change takes effect.
    pub time: f64,
    /// Machine index (dense; `Add` of index `n_machines()` appends).
    pub machine: u32,
    /// What happens.
    pub kind: ClusterEventKind,
}

impl ClusterEvent {
    /// Serialize bit-exactly for wire transport (distributed sweeps).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{f64_to_json, Json};
        let (kind, cap) = match self.kind {
            ClusterEventKind::Add(r) => ("add", Some(r)),
            ClusterEventKind::Remove => ("remove", None),
            ClusterEventKind::Update(r) => ("update", Some(r)),
        };
        let mut fields = vec![
            ("time", f64_to_json(self.time)),
            ("machine", Json::num(self.machine as f64)),
            ("kind", Json::str(kind)),
        ];
        if let Some(r) = cap {
            fields.push(("cap", r.to_json()));
        }
        Json::obj(fields)
    }

    /// Inverse of [`ClusterEvent::to_json`]; `None` on shape mismatch.
    pub fn from_json(v: &crate::util::json::Json) -> Option<ClusterEvent> {
        use crate::util::json::f64_from_json;
        let kind = match v.get("kind").as_str()? {
            "add" => ClusterEventKind::Add(Resources::from_json(v.get("cap"))?),
            "remove" => ClusterEventKind::Remove,
            "update" => ClusterEventKind::Update(Resources::from_json(v.get("cap"))?),
            _ => return None,
        };
        Some(ClusterEvent {
            time: f64_from_json(v.get("time"))?,
            machine: v.get("machine").as_u64()? as u32,
            kind,
        })
    }
}

/// A recorded placement of `n` identical components across machines;
/// releasable via [`Cluster::release`]. An empty `by_machine` means
/// "nothing placed" — the dense per-request stores in the schedulers use
/// that as the absent state and reuse the buffer across admissions.
/// (`PartialEq` because placements travel inside
/// [`crate::sched::Decision`]s, which tests compare wholesale.)
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Placement {
    /// Per-component resource demand of this placement.
    pub res: Resources,
    /// (machine index, component count) pairs.
    pub by_machine: Vec<(u32, u32)>,
}

impl Placement {
    /// Total number of placed components.
    pub fn count(&self) -> u32 {
        self.by_machine.iter().map(|&(_, k)| k).sum()
    }

    /// Is anything recorded?
    pub fn is_empty(&self) -> bool {
        self.by_machine.is_empty()
    }

    /// Does any component of this placement sit on `machine`?
    pub fn touches(&self, machine: u32) -> bool {
        self.by_machine.iter().any(|&(mi, _)| mi == machine)
    }

    /// Drop every component recorded on `machine` and return how many
    /// were dropped. Used when `machine` died: its components are gone,
    /// and their capacity must **not** be released back (the machine's
    /// free space vanished with it) — the caller just forgets them.
    pub fn remove_machine(&mut self, machine: u32) -> u32 {
        let mut dropped = 0;
        self.by_machine.retain(|&(mi, k)| {
            if mi == machine {
                dropped += k;
                false
            } else {
                true
            }
        });
        dropped
    }
}

/// The cluster: a vector of machines (uniform in the paper's simulations:
/// 100 × (32 cores, 128 GB), §4.1).
///
/// `used` is tracked incrementally — `used()` is O(1), it is read on every
/// simulator event for the allocation metrics (§Perf).
#[derive(Clone, Debug)]
pub struct Cluster {
    machines: Vec<Machine>,
    used: Resources,
    total: Resources,
    /// Componentwise max of `free` per machine block (free-capacity index).
    blk_max: Vec<Resources>,
    /// First block that may hold any free capacity at all; blocks before
    /// it are fully exhausted (free ≤ 0 in both dimensions).
    open_from: usize,
}

impl Cluster {
    /// A cluster over an explicit machine list.
    pub fn new(machines: Vec<Machine>) -> Self {
        assert!(!machines.is_empty());
        let mut total = Resources::ZERO;
        for m in &machines {
            total.add(&m.total);
        }
        let n_blocks = (machines.len() + BLOCK - 1) / BLOCK;
        let mut c = Cluster {
            machines,
            used: Resources::ZERO,
            total,
            blk_max: vec![Resources::ZERO; n_blocks],
            open_from: 0,
        };
        c.rebuild_index();
        c
    }

    /// `n` identical machines.
    pub fn uniform(n: usize, per_machine: Resources) -> Self {
        Cluster::new(vec![Machine::new(per_machine); n])
    }

    /// The paper's simulated cluster: 100 machines × 32 cores × 128 GB.
    pub fn paper_sim() -> Self {
        Cluster::uniform(100, Resources::new(32.0, 128.0 * 1024.0))
    }

    /// A single abstract machine of `units` 1-CPU units — the 1-D model of
    /// the illustrative example (Fig. 1).
    pub fn units(units: u32) -> Self {
        Cluster::uniform(1, Resources::new(units as f64, units as f64))
    }

    /// Number of machines.
    pub fn n_machines(&self) -> usize {
        self.machines.len()
    }

    /// The machines, in placement (index) order.
    pub fn machines(&self) -> &[Machine] {
        &self.machines
    }

    /// Installed capacities in machine-index order — enough to rebuild an
    /// **empty** cluster on another host ([`Cluster::from_capacities`]).
    /// Plans ship clusters in their pre-run (all-free) state, so free
    /// vectors need not travel.
    pub fn capacities(&self) -> Vec<Resources> {
        self.machines.iter().map(|m| m.total).collect()
    }

    /// An empty cluster with the given installed capacities (inverse of
    /// [`Cluster::capacities`] for a cluster nothing was placed on).
    pub fn from_capacities(caps: Vec<Resources>) -> Self {
        Cluster::new(caps.into_iter().map(Machine::new).collect())
    }

    // ---- free-capacity index maintenance ---------------------------------

    /// Recompute the max-free vector of block `b` from its machines.
    fn rebuild_block(&mut self, b: usize) {
        let lo = b * BLOCK;
        let hi = (lo + BLOCK).min(self.machines.len());
        let mut mx = Resources::ZERO;
        for m in &self.machines[lo..hi] {
            if m.free.cpu > mx.cpu {
                mx.cpu = m.free.cpu;
            }
            if m.free.ram_mb > mx.ram_mb {
                mx.ram_mb = m.free.ram_mb;
            }
        }
        self.blk_max[b] = mx;
    }

    /// Rebuild the whole index (bulk free-state changes).
    fn rebuild_index(&mut self) {
        for b in 0..self.blk_max.len() {
            self.rebuild_block(b);
        }
        self.open_from = 0;
    }

    /// A block is exhausted when no machine in it has any free capacity.
    #[inline]
    fn block_exhausted(&self, b: usize) -> bool {
        let mx = &self.blk_max[b];
        mx.cpu <= 0.0 && mx.ram_mb <= 0.0
    }

    /// Advance and return the open-block cursor.
    #[inline]
    fn advance_cursor(&mut self) -> usize {
        while self.open_from < self.blk_max.len() && self.block_exhausted(self.open_from) {
            self.open_from += 1;
        }
        self.open_from
    }

    /// Does the cursor apply to this component size? Exhausted machines
    /// (free ≤ 0 in both dims) can still "fit" components whose demand is
    /// below the 1e-9 fit tolerance, so near-zero demands scan from 0.
    #[inline]
    fn cursor_applies(res: &Resources) -> bool {
        res.cpu > 1e-9 || res.ram_mb > 1e-9
    }

    /// Reset all machines to empty (start of a virtual-assignment pass).
    pub fn clear(&mut self) {
        for m in &mut self.machines {
            m.free = m.total;
        }
        self.used = Resources::ZERO;
        self.rebuild_index();
    }

    /// Aggregate capacity (O(1), cached).
    pub fn total(&self) -> Resources {
        self.total
    }

    /// Quick reject: can even one component of `res` fit *anywhere*?
    /// (Aggregate check — machine scan only happens when it might.)
    #[inline]
    fn aggregate_can_fit_one(&self, res: &Resources) -> bool {
        let free_cpu = self.total.cpu - self.used.cpu;
        let free_ram = self.total.ram_mb - self.used.ram_mb;
        res.cpu <= free_cpu + 1e-9 && res.ram_mb <= free_ram + 1e-9
    }

    /// Aggregate currently-used resources (O(1), tracked incrementally).
    pub fn used(&self) -> Resources {
        self.used
    }

    /// Componentwise max free vector across all machines, straight off
    /// the block index (O(blocks)). A demand that does not [`fit_in`]
    /// this vector fits no machine — the same per-block maxima
    /// [`Cluster::can_place_all`] prunes with, so a reject here is exact:
    /// every placement probe for that demand would fail.
    ///
    /// [`fit_in`]: Resources::fits_in
    pub fn max_free(&self) -> Resources {
        let mut mx = Resources::ZERO;
        for b in &self.blk_max {
            if b.cpu > mx.cpu {
                mx.cpu = b.cpu;
            }
            if b.ram_mb > mx.ram_mb {
                mx.ram_mb = b.ram_mb;
            }
        }
        mx
    }

    /// How many components of `res` fit cluster-wide right now.
    pub fn fit_count(&self, res: &Resources) -> u64 {
        if !self.aggregate_can_fit_one(res) {
            return 0;
        }
        let mut count = 0u64;
        for b in 0..self.blk_max.len() {
            if !res.fits_in(&self.blk_max[b]) {
                continue; // no machine in this block fits even one
            }
            let lo = b * BLOCK;
            let hi = (lo + BLOCK).min(self.machines.len());
            for m in &self.machines[lo..hi] {
                count += m.fit_count(res) as u64;
            }
        }
        count
    }

    /// All-or-nothing feasibility **without mutating anything**: would
    /// `place_all` succeed? Early-exits as soon as `n` components are
    /// known to fit. Exactly equivalent to `fit_count(res) >= n`.
    pub fn can_place_all(&self, res: &Resources, n: u32) -> bool {
        if n == 0 {
            return true;
        }
        if !self.aggregate_can_fit_one(res) {
            return false;
        }
        let need = n as u64;
        let mut acc = 0u64;
        for b in 0..self.blk_max.len() {
            if !res.fits_in(&self.blk_max[b]) {
                continue;
            }
            let lo = b * BLOCK;
            let hi = (lo + BLOCK).min(self.machines.len());
            for m in &self.machines[lo..hi] {
                acc += m.fit_count(res) as u64;
                if acc >= need {
                    return true;
                }
            }
        }
        false
    }

    /// Greedy first-fit core: place up to `n` components of `res` in
    /// machine-index order, optionally recording (machine, count) pairs.
    /// Exactly the same fill order as a full scan from machine 0 —
    /// skipped blocks are those where every machine's `fit_count` is 0.
    fn place_internal(
        &mut self,
        res: &Resources,
        n: u32,
        mut record: Option<&mut Vec<(u32, u32)>>,
    ) -> u32 {
        if n == 0 || !self.aggregate_can_fit_one(res) {
            return 0;
        }
        let start = if Self::cursor_applies(res) {
            self.advance_cursor()
        } else {
            0
        };
        let n_blocks = self.blk_max.len();
        let mut left = n;
        for b in start..n_blocks {
            if left == 0 {
                break;
            }
            if !res.fits_in(&self.blk_max[b]) {
                continue;
            }
            let lo = b * BLOCK;
            let hi = (lo + BLOCK).min(self.machines.len());
            let mut touched = false;
            for i in lo..hi {
                if left == 0 {
                    break;
                }
                let m = &mut self.machines[i];
                let k = m.fit_count(res).min(left);
                if k > 0 {
                    m.free.sub(&res.scaled(k as f64));
                    left -= k;
                    touched = true;
                    if let Some(rec) = record.as_mut() {
                        rec.push((i as u32, k));
                    }
                }
            }
            if touched {
                self.rebuild_block(b);
            }
        }
        let placed = n - left;
        self.used.add(&res.scaled(placed as f64));
        placed
    }

    /// Place up to `n` components of `res`, greedily filling machines in
    /// order. Returns how many were placed.
    pub fn place_up_to(&mut self, res: &Resources, n: u32) -> u32 {
        self.place_internal(res, n, None)
    }

    /// All-or-nothing placement of `n` components of `res`.
    /// Feasibility is checked first (without mutation), then committed.
    pub fn place_all(&mut self, res: &Resources, n: u32) -> bool {
        if !self.can_place_all(res, n) {
            return false;
        }
        let placed = self.place_up_to(res, n);
        debug_assert_eq!(placed, n);
        true
    }

    /// Place up to `n` components of `res`, recording which machines got
    /// how many — so the placement can later be released exactly
    /// (persistent-placement schedulers, e.g. the rigid baseline, and the
    /// Zoe back-end).
    pub fn place_up_to_tracked(&mut self, res: &Resources, n: u32) -> (u32, Placement) {
        let mut p = Placement {
            res: *res,
            by_machine: Vec::new(),
        };
        let placed = self.place_internal(res, n, Some(&mut p.by_machine));
        (placed, p)
    }

    /// Tracked placement into a caller-owned buffer (cleared first); the
    /// buffer's allocation is reused across calls.
    pub fn place_up_to_into(&mut self, res: &Resources, n: u32, p: &mut Placement) -> u32 {
        p.res = *res;
        p.by_machine.clear();
        self.place_internal(res, n, Some(&mut p.by_machine))
    }

    /// Tracked placement **appended** to an existing buffer holding the
    /// same component size (malleable top-ups: grants only grow, so the
    /// placement accumulates (machine, count) pairs).
    pub fn place_up_to_append(&mut self, res: &Resources, n: u32, p: &mut Placement) -> u32 {
        debug_assert!(p.by_machine.is_empty() || p.res == *res);
        p.res = *res;
        self.place_internal(res, n, Some(&mut p.by_machine))
    }

    /// All-or-nothing tracked placement.
    pub fn place_all_tracked(&mut self, res: &Resources, n: u32) -> Option<Placement> {
        if !self.can_place_all(res, n) {
            return None;
        }
        let (placed, p) = self.place_up_to_tracked(res, n);
        debug_assert_eq!(placed, n);
        Some(p)
    }

    /// All-or-nothing tracked placement into a caller-owned buffer.
    /// On failure the buffer is left cleared.
    pub fn place_all_into(&mut self, res: &Resources, n: u32, p: &mut Placement) -> bool {
        p.res = *res;
        p.by_machine.clear();
        if !self.can_place_all(res, n) {
            return false;
        }
        let placed = self.place_internal(res, n, Some(&mut p.by_machine));
        debug_assert_eq!(placed, n);
        true
    }

    /// Release the `n` **newest** components of `p` (from the tail of
    /// its (machine, count) pairs — matching
    /// [`crate::sched::Decision::Reclaim`]'s newest-first container
    /// kill order) back to the cluster, shrinking the buffer in place.
    /// Returns how many were actually released (bounded by `p.count()`).
    /// The SLO reclaim path uses this to carve elastic capacity out of a
    /// slack donor without disturbing its older components.
    pub fn release_n(&mut self, p: &mut Placement, n: u32) -> u32 {
        let mut left = n;
        while left > 0 {
            let Some(&(mi, k)) = p.by_machine.last() else { break };
            let take = k.min(left);
            let m = &mut self.machines[mi as usize];
            m.free.add(&p.res.scaled(take as f64));
            debug_assert!(m.free.cpu <= m.total.cpu + 1e-6);
            let free = m.free;
            self.index_grew(mi as usize, free);
            left -= take;
            if take == k {
                p.by_machine.pop();
            } else {
                p.by_machine.last_mut().unwrap().1 = k - take;
            }
        }
        let released = n - left;
        self.used.sub(&p.res.scaled(released as f64));
        released
    }

    /// All-or-nothing **spread** (worst-fit) placement into a
    /// caller-owned buffer: each of the `n` components goes to the
    /// machine with the most free capacity that still fits it (most
    /// free CPU, then most free RAM, then lowest index), instead of the
    /// greedy first-fit pack. Spreading an app's core components across
    /// machines cuts the failure blast radius — one dead machine
    /// requeues fewer apps — at the cost of locality and of an O(n·m)
    /// scan (spread is an opt-in placement mode, not the hot default).
    /// On failure the buffer is left cleared and nothing is consumed.
    pub fn place_all_spread_into(&mut self, res: &Resources, n: u32, p: &mut Placement) -> bool {
        p.res = *res;
        p.by_machine.clear();
        if !self.can_place_all(res, n) {
            return false;
        }
        // `can_place_all` ⇒ every pick below succeeds: placing one
        // component on a fitting machine lowers total fit count by
        // exactly one, regardless of which machine is chosen.
        for _ in 0..n {
            let mut best = usize::MAX;
            for (i, m) in self.machines.iter().enumerate() {
                if m.fit_count(res) == 0 {
                    continue;
                }
                if best == usize::MAX {
                    best = i;
                    continue;
                }
                let b = &self.machines[best];
                if m.free.cpu > b.free.cpu + 1e-9
                    || ((m.free.cpu - b.free.cpu).abs() <= 1e-9
                        && m.free.ram_mb > b.free.ram_mb + 1e-9)
                {
                    best = i;
                }
            }
            debug_assert!(best != usize::MAX, "can_place_all lied");
            self.machines[best].free.sub(res);
            match p.by_machine.iter_mut().find(|&&mut (mi, _)| mi as usize == best) {
                Some(&mut (_, ref mut k)) => *k += 1,
                None => p.by_machine.push((best as u32, 1)),
            }
        }
        // Canonical machine-index order (release/apply paths expect
        // non-decreasing block indices for single-pass rebuilds).
        p.by_machine.sort_unstable_by_key(|&(mi, _)| mi);
        for &(mi, _) in &p.by_machine {
            self.rebuild_block(mi as usize / BLOCK);
        }
        self.used.add(&res.scaled(n as f64));
        true
    }

    /// Release a tracked placement held in a reusable buffer and clear
    /// the buffer (the schedulers' "absent" state). No-op when empty.
    pub fn release_and_clear(&mut self, p: &mut Placement) {
        if !p.by_machine.is_empty() {
            self.release(p);
            p.by_machine.clear();
        }
    }

    /// Release a tracked placement.
    pub fn release(&mut self, p: &Placement) {
        let mut released = 0u32;
        for &(mi, k) in &p.by_machine {
            let m = &mut self.machines[mi as usize];
            m.free.add(&p.res.scaled(k as f64));
            released += k;
            debug_assert!(m.free.cpu <= m.total.cpu + 1e-6);
            debug_assert!(m.free.ram_mb <= m.total.ram_mb + 1e-3);
            // Free only grew: the block max update is O(1).
            let free = m.free;
            let b = mi as usize / BLOCK;
            let mx = &mut self.blk_max[b];
            if free.cpu > mx.cpu {
                mx.cpu = free.cpu;
            }
            if free.ram_mb > mx.ram_mb {
                mx.ram_mb = free.ram_mb;
            }
            if b < self.open_from {
                self.open_from = b;
            }
        }
        self.used.sub(&p.res.scaled(released as f64));
    }

    /// Re-apply a tracked placement **verbatim** — the decision cache's
    /// replay path: consume exactly the capacity `p` records without
    /// re-running the greedy search.
    ///
    /// Bitwise contract: called on a cluster whose free vectors equal
    /// (bit-for-bit) the state the placement was originally computed
    /// against, this leaves every free vector, `blk_max` entry and the
    /// `used` aggregate bit-identical to what [`Cluster::place_up_to`]
    /// would have produced. The scan cursor (`open_from`) is *not*
    /// advanced — it only ever skips exhausted blocks, so a lower cursor
    /// never changes placement results, only re-scans them.
    ///
    /// An empty placement is a no-op (the search paths' zero-placed
    /// `used.add(+0.0)` is a bitwise no-op too: `used` is never `-0.0`).
    pub fn apply_placement(&mut self, p: &Placement) {
        if p.by_machine.is_empty() {
            return;
        }
        let mut applied = 0u32;
        // by_machine is machine-index-ordered (the greedy scan emits it
        // that way), so block indices are non-decreasing: rebuilding on
        // each block change + once at the end rebuilds every touched
        // block exactly once, matching the search path. Out-of-order
        // pairs would only cost redundant rebuilds, never correctness.
        let mut cur_block = usize::MAX;
        for &(mi, k) in &p.by_machine {
            let b = mi as usize / BLOCK;
            if b != cur_block {
                if cur_block != usize::MAX {
                    self.rebuild_block(cur_block);
                }
                cur_block = b;
            }
            let m = &mut self.machines[mi as usize];
            m.free.sub(&p.res.scaled(k as f64));
            applied += k;
            debug_assert!(m.free.cpu >= -1e-6, "apply_placement over-committed cpu");
            debug_assert!(m.free.ram_mb >= -1e-3, "apply_placement over-committed ram");
        }
        self.rebuild_block(cur_block);
        self.used.add(&p.res.scaled(applied as f64));
    }

    /// Snapshot of the free vectors (and used total), for trial
    /// placements.
    pub fn save(&self) -> Snapshot {
        Snapshot {
            free: self.machines.iter().map(|m| m.free).collect(),
            used: self.used,
        }
    }

    /// Restore a snapshot taken with [`Cluster::save`].
    pub fn restore(&mut self, snap: &Snapshot) {
        debug_assert_eq!(snap.free.len(), self.machines.len());
        for (m, f) in self.machines.iter_mut().zip(&snap.free) {
            m.free = *f;
        }
        self.used = snap.used;
        self.rebuild_index();
    }

    // ---- dynamic capacity (churn / failure injection) --------------------

    /// Installed capacity of machine `idx` (zero while it is down).
    pub fn machine_total(&self, idx: u32) -> Resources {
        self.machines[idx as usize].total
    }

    /// Is machine `idx` currently down (capacity removed)?
    pub fn is_down(&self, idx: u32) -> bool {
        let t = self.machines[idx as usize].total;
        t.cpu <= 0.0 && t.ram_mb <= 0.0
    }

    /// O(1) block-max/cursor update after machine `idx` gained free
    /// capacity `free` (add/restore/grow paths).
    #[inline]
    fn index_grew(&mut self, idx: usize, free: Resources) {
        let b = idx / BLOCK;
        let mx = &mut self.blk_max[b];
        if free.cpu > mx.cpu {
            mx.cpu = free.cpu;
        }
        if free.ram_mb > mx.ram_mb {
            mx.ram_mb = free.ram_mb;
        }
        if b < self.open_from {
            self.open_from = b;
        }
    }

    /// Append a brand-new empty machine of capacity `res`; returns its
    /// index. O(1) (the free-capacity index only grows).
    pub fn add_machine(&mut self, res: Resources) -> u32 {
        let idx = self.machines.len();
        self.machines.push(Machine::new(res));
        self.total.add(&res);
        if idx / BLOCK >= self.blk_max.len() {
            self.blk_max.push(Resources::ZERO);
        }
        self.index_grew(idx, res);
        idx as u32
    }

    /// Machine `idx` dies: everything allocated on it vanishes (the
    /// caller is responsible for purging placements that reference it —
    /// see [`Placement::remove_machine`]; releasing them here would
    /// resurrect capacity that no longer exists). Returns the installed
    /// capacity that was removed, so the caller can restore it later.
    pub fn fail_machine(&mut self, idx: u32) -> Resources {
        let i = idx as usize;
        let m = &mut self.machines[i];
        let cap = m.total;
        let mut in_use = m.total;
        in_use.sub(&m.free);
        self.used.sub(&in_use);
        self.total.sub(&cap);
        m.total = Resources::ZERO;
        m.free = Resources::ZERO;
        // The block max can only have shrunk: recompute it exactly.
        self.rebuild_block(i / BLOCK);
        cap
    }

    /// A previously failed machine comes back empty with capacity `res`.
    pub fn restore_machine(&mut self, idx: u32, res: Resources) {
        let i = idx as usize;
        debug_assert!(self.is_down(idx), "restore_machine on a live machine");
        let m = &mut self.machines[i];
        m.total = res;
        m.free = res;
        self.total.add(&res);
        self.index_grew(i, res);
    }

    /// Try to resize machine `idx` to installed capacity `res` without
    /// disturbing what is allocated on it. Succeeds (and returns `true`)
    /// iff the current in-use amount still fits `res`; otherwise nothing
    /// changes and the caller must treat the update as a kill
    /// ([`Cluster::fail_machine`] + [`Cluster::restore_machine`]).
    pub fn try_resize_machine(&mut self, idx: u32, res: Resources) -> bool {
        let i = idx as usize;
        let m = &mut self.machines[i];
        let mut in_use = m.total;
        in_use.sub(&m.free);
        if !in_use.fits_in(&res) {
            return false;
        }
        self.total.sub(&m.total);
        self.total.add(&res);
        m.total = res;
        let mut free = res;
        free.sub(&in_use);
        m.free = free;
        // Free may have shrunk or grown: recompute the block, then let
        // the cursor re-open it if it grew.
        self.rebuild_block(i / BLOCK);
        self.index_grew(i, free);
        true
    }

    /// Release only the components of `p` **not** on machine `dead`
    /// (whose capacity vanished with it), then clear the buffer. The
    /// requeue path: a failed app's surviving components free their
    /// machines; the dead machine's components are simply forgotten.
    pub fn release_excluding(&mut self, p: &mut Placement, dead: u32) {
        p.remove_machine(dead);
        self.release_and_clear(p);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_cluster_counts() {
        let c = Cluster::units(10);
        assert_eq!(c.fit_count(&Resources::new(1.0, 1.0)), 10);
        assert_eq!(c.total().cpu, 10.0);
    }

    #[test]
    fn place_up_to_partial() {
        let mut c = Cluster::units(10);
        let unit = Resources::new(1.0, 1.0);
        assert_eq!(c.place_up_to(&unit, 7), 7);
        assert_eq!(c.place_up_to(&unit, 7), 3);
        assert_eq!(c.place_up_to(&unit, 7), 0);
        assert_eq!(c.used().cpu, 10.0);
    }

    #[test]
    fn apply_placement_mirrors_the_search_bitwise() {
        // A multi-block cluster with odd sizes so the floats are not
        // round: place, snapshot the searched result, rewind, re-apply
        // the tracked placement, and demand bit-equality everywhere.
        let mut c = Cluster::uniform(3 * BLOCK, Resources::new(3.7, 11.3));
        let res = Resources::new(1.3, 2.9);
        // Pre-consume unevenly so the placement spans machines/blocks.
        let (pre, _) = c.place_up_to_tracked(&Resources::new(2.0, 2.0), (2 * BLOCK) as u32);
        assert_eq!(pre as usize, 2 * BLOCK);
        let pre_snap = c.save();
        let (n, p) = c.place_up_to_tracked(&res, (BLOCK + 3) as u32);
        assert!(n > 0);
        let searched = c.save();
        let searched_used = c.used();
        // Rewind to the exact pre-placement bits, then replay verbatim.
        c.restore(&pre_snap);
        c.apply_placement(&p);
        let replayed = c.save();
        assert_eq!(c.used().cpu.to_bits(), searched_used.cpu.to_bits());
        assert_eq!(c.used().ram_mb.to_bits(), searched_used.ram_mb.to_bits());
        for (a, b) in searched.free.iter().zip(&replayed.free) {
            assert_eq!(a.cpu.to_bits(), b.cpu.to_bits());
            assert_eq!(a.ram_mb.to_bits(), b.ram_mb.to_bits());
        }
        // And the cluster still places correctly afterwards (blk_max
        // stayed coherent): a full re-search finds the same capacity.
        let before = c.fit_count(&res);
        let placed = c.place_up_to(&res, u32::MAX);
        assert_eq!(placed, before);
        // Empty placements are no-ops.
        let empty = Placement { res, by_machine: Vec::new() };
        let snap = c.save();
        c.apply_placement(&empty);
        let after = c.save();
        assert_eq!(snap.used.cpu.to_bits(), after.used.cpu.to_bits());
    }

    #[test]
    fn place_all_is_transactional() {
        let mut c = Cluster::units(10);
        let unit = Resources::new(1.0, 1.0);
        assert!(c.place_all(&unit, 10));
        assert!(!c.place_all(&unit, 1));
        c.clear();
        assert!(!c.place_all(&unit, 11));
        // failed place_all must not consume anything
        assert_eq!(c.used().cpu, 0.0);
    }

    #[test]
    fn two_dimensional_fit() {
        // Machine with plenty CPU but tight RAM.
        let mut c = Cluster::uniform(1, Resources::new(32.0, 4096.0));
        let comp = Resources::new(1.0, 2048.0);
        assert_eq!(c.fit_count(&comp), 2);
        assert_eq!(c.place_up_to(&comp, 5), 2);
    }

    #[test]
    fn fragmentation_across_machines() {
        // 2 machines × 4 cores; a 5-core component fits nowhere even though
        // aggregate capacity is 8.
        let c = Cluster::uniform(2, Resources::new(4.0, 1e6));
        assert_eq!(c.fit_count(&Resources::new(5.0, 1.0)), 0);
        assert_eq!(c.fit_count(&Resources::new(2.0, 1.0)), 4);
    }

    #[test]
    fn save_restore() {
        let mut c = Cluster::units(10);
        let unit = Resources::new(1.0, 1.0);
        c.place_up_to(&unit, 4);
        let snap = c.save();
        c.place_up_to(&unit, 6);
        assert_eq!(c.used().cpu, 10.0);
        c.restore(&snap);
        assert_eq!(c.used().cpu, 4.0);
    }

    #[test]
    fn zero_resource_component_fits_infinitely() {
        let c = Cluster::units(1);
        assert!(c.fit_count(&Resources::ZERO) > 1_000_000);
    }

    #[test]
    fn can_place_all_matches_fit_count() {
        // Fill a multi-block cluster irregularly, then check the
        // non-mutating feasibility answer against fit_count on a range
        // of component sizes and counts.
        let mut c = Cluster::uniform(40, Resources::new(8.0, 16.0 * 1024.0));
        let mut rng = crate::util::rng::Rng::new(0xF00D);
        for _ in 0..200 {
            let res = Resources::new(
                rng.range_f64(0.25, 6.0),
                rng.range_f64(128.0, 8.0 * 1024.0),
            );
            c.place_up_to(&res, rng.range_u64(1, 8) as u32);
        }
        for _ in 0..200 {
            let res = Resources::new(
                rng.range_f64(0.25, 9.0),
                rng.range_f64(128.0, 20.0 * 1024.0),
            );
            let n = rng.range_u64(1, 30) as u32;
            assert_eq!(
                c.can_place_all(&res, n),
                c.fit_count(&res) >= n as u64,
                "res={res:?} n={n}"
            );
        }
    }

    #[test]
    fn indexed_placement_identical_to_full_scan() {
        // The same random place/release sequence on an indexed cluster and
        // on a reference built by brute force (restore rebuilds the index,
        // so compare per-machine free vectors after each operation).
        let mut a = Cluster::uniform(37, Resources::new(4.0, 4096.0));
        let mut rng = crate::util::rng::Rng::new(0xBEE);
        let mut live: Vec<Placement> = Vec::new();
        for step in 0..400 {
            if !live.is_empty() && rng.chance(0.4) {
                let i = rng.below(live.len() as u64) as usize;
                let p = live.swap_remove(i);
                a.release(&p);
            } else {
                let res = Resources::new(
                    rng.range_f64(0.25, 3.0),
                    rng.range_f64(64.0, 2048.0),
                );
                let n = rng.range_u64(1, 12) as u32;
                let (placed, p) = a.place_up_to_tracked(&res, n);
                if placed > 0 {
                    live.push(p);
                }
            }
            // Invariant: the index never hides capacity — fit_count via
            // blocks equals a brute-force machine scan.
            let probe = Resources::new(rng.range_f64(0.25, 4.0), rng.range_f64(64.0, 4096.0));
            let brute: u64 = a.machines().iter().map(|m| m.fit_count(&probe) as u64).sum();
            assert_eq!(a.fit_count(&probe), brute, "step {step}");
        }
    }

    #[test]
    fn reusable_buffers_round_trip() {
        let mut c = Cluster::units(10);
        let unit = Resources::new(1.0, 1.0);
        let mut p = Placement::default();
        assert_eq!(c.place_up_to_into(&unit, 4, &mut p), 4);
        assert_eq!(p.count(), 4);
        c.release(&p);
        assert_eq!(c.used().cpu, 0.0);
        // Reuse the same buffer.
        assert!(c.place_all_into(&unit, 10, &mut p));
        assert_eq!(p.count(), 10);
        assert!(!c.place_all_into(&unit, 1, &mut p));
        assert!(p.is_empty(), "failed all-or-nothing leaves the buffer clear");
        // Clearing the buffer does not touch the cluster: the 10 units from
        // the successful placement above are still held.
        assert_eq!(c.used().cpu, 10.0);
        c.clear();
        assert_eq!(c.used().cpu, 0.0);
    }

    #[test]
    fn fail_and_restore_round_trip() {
        let mut c = Cluster::uniform(2, Resources::new(4.0, 1e6));
        let unit = Resources::new(1.0, 1.0);
        let (placed, mut p) = c.place_up_to_tracked(&unit, 6);
        assert_eq!(placed, 6); // 4 on machine 0, 2 on machine 1
        let cap = c.fail_machine(0);
        assert_eq!(cap.cpu, 4.0);
        assert!(c.is_down(0));
        assert_eq!(c.total().cpu, 4.0);
        // Only machine 1's two components remain in use.
        assert_eq!(c.used().cpu, 2.0);
        // Requeue path: forget the dead components, free the survivors.
        c.release_excluding(&mut p, 0);
        assert_eq!(c.used().cpu, 0.0);
        assert!(p.is_empty());
        c.restore_machine(0, cap);
        assert!(!c.is_down(0));
        assert_eq!(c.total().cpu, 8.0);
        assert_eq!(c.fit_count(&unit), 8);
    }

    #[test]
    fn add_machine_extends_cluster() {
        let mut c = Cluster::uniform(BLOCK, Resources::new(2.0, 1e6));
        let unit = Resources::new(1.0, 1.0);
        assert_eq!(c.place_up_to(&unit, 64), 32);
        let idx = c.add_machine(Resources::new(2.0, 1e6));
        assert_eq!(idx as usize, BLOCK); // opens a new block
        assert_eq!(c.place_up_to(&unit, 64), 2);
        let brute: u64 = c.machines().iter().map(|m| m.fit_count(&unit) as u64).sum();
        assert_eq!(c.fit_count(&unit), brute);
    }

    #[test]
    fn resize_within_free_keeps_allocation() {
        let mut c = Cluster::uniform(1, Resources::new(8.0, 1e6));
        let unit = Resources::new(1.0, 1.0);
        assert_eq!(c.place_up_to(&unit, 3), 3);
        // Shrink to 4 cores: 3 in use still fit.
        assert!(c.try_resize_machine(0, Resources::new(4.0, 1e6)));
        assert_eq!(c.total().cpu, 4.0);
        assert_eq!(c.used().cpu, 3.0);
        assert_eq!(c.fit_count(&unit), 1);
        // Shrink below the in-use amount: refused, nothing changes.
        assert!(!c.try_resize_machine(0, Resources::new(2.0, 1e6)));
        assert_eq!(c.total().cpu, 4.0);
        // Grow re-opens capacity.
        assert!(c.try_resize_machine(0, Resources::new(16.0, 1e6)));
        assert_eq!(c.fit_count(&unit), 13);
    }

    #[test]
    fn release_n_frees_newest_first() {
        let mut c = Cluster::uniform(3, Resources::new(4.0, 1e6));
        let unit = Resources::new(1.0, 1.0);
        let (placed, mut p) = c.place_up_to_tracked(&unit, 10);
        assert_eq!(placed, 10); // (0,4) (1,4) (2,2)
        // Release 3: takes machine 2's pair (2) then one from machine 1.
        assert_eq!(c.release_n(&mut p, 3), 3);
        assert_eq!(p.count(), 7);
        assert_eq!(p.by_machine, vec![(0, 4), (1, 3)]);
        assert_eq!(c.used().cpu, 7.0);
        assert_eq!(c.machines()[2].free.cpu, 4.0);
        // Over-asking releases only what is held.
        assert_eq!(c.release_n(&mut p, 100), 7);
        assert!(p.is_empty());
        assert_eq!(c.used().cpu, 0.0);
        // The index stayed coherent.
        assert_eq!(c.fit_count(&unit), 12);
    }

    #[test]
    fn spread_placement_distributes_worst_fit() {
        let mut c = Cluster::uniform(3, Resources::new(4.0, 1e6));
        let unit = Resources::new(1.0, 1.0);
        let mut p = Placement::default();
        // First-fit would pack all 3 on machine 0; worst-fit rotates.
        assert!(c.place_all_spread_into(&unit, 3, &mut p));
        assert_eq!(p.by_machine, vec![(0, 1), (1, 1), (2, 1)]);
        // A second spread app lands one per machine again.
        let mut q = Placement::default();
        assert!(c.place_all_spread_into(&unit, 3, &mut q));
        assert_eq!(q.by_machine, vec![(0, 1), (1, 1), (2, 1)]);
        assert_eq!(c.used().cpu, 6.0);
        // Infeasible stays transactional.
        let mut r = Placement::default();
        assert!(!c.place_all_spread_into(&Resources::new(5.0, 1.0), 1, &mut r));
        assert!(r.is_empty());
        assert_eq!(c.used().cpu, 6.0);
        // Release round-trips and the index stays coherent with a
        // brute-force scan.
        c.release(&p);
        c.release(&q);
        let brute: u64 = c.machines().iter().map(|m| m.fit_count(&unit) as u64).sum();
        assert_eq!(c.fit_count(&unit), brute);
        assert_eq!(brute, 12);
    }

    #[test]
    fn placement_remove_machine_counts_dropped() {
        let mut p = Placement {
            res: Resources::new(1.0, 1.0),
            by_machine: vec![(0, 3), (2, 1), (0, 2)],
        };
        assert!(p.touches(0));
        assert_eq!(p.remove_machine(0), 5);
        assert!(!p.touches(0));
        assert_eq!(p.count(), 1);
        assert_eq!(p.remove_machine(7), 0);
    }

    #[test]
    fn append_accumulates_topups() {
        let mut c = Cluster::uniform(3, Resources::new(4.0, 1e6));
        let unit = Resources::new(1.0, 1.0);
        let mut p = Placement::default();
        assert_eq!(c.place_up_to_append(&unit, 5, &mut p), 5);
        assert_eq!(c.place_up_to_append(&unit, 4, &mut p), 4);
        assert_eq!(p.count(), 9);
        c.release(&p);
        assert_eq!(c.used().cpu, 0.0);
        assert_eq!(c.fit_count(&unit), 12);
    }
}

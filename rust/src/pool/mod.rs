//! Cluster resource pool: a set of machines with 2-D capacities
//! (CPU, RAM) on which the schedulers trial-place application components.
//!
//! The schedulers compute *virtual assignments* (§3.2): on every event the
//! assignment is recomputed from scratch against a cleared pool, so the
//! pool exposes bulk placement of homogeneous component batches plus
//! cheap save/restore for admission trials.

use crate::core::Resources;

/// One machine: total and currently-free resources.
#[derive(Clone, Copy, Debug)]
pub struct Machine {
    pub total: Resources,
    pub free: Resources,
}

impl Machine {
    pub fn new(total: Resources) -> Self {
        Machine { total, free: total }
    }

    /// How many components of `res` fit in the free space.
    #[inline]
    pub fn fit_count(&self, res: &Resources) -> u32 {
        let by_cpu = if res.cpu > 0.0 {
            ((self.free.cpu + 1e-9) / res.cpu) as u32
        } else {
            u32::MAX
        };
        let by_ram = if res.ram_mb > 0.0 {
            ((self.free.ram_mb + 1e-9) / res.ram_mb) as u32
        } else {
            u32::MAX
        };
        by_cpu.min(by_ram)
    }
}

/// A saved cluster state for trial placements.
#[derive(Clone, Debug)]
pub struct Snapshot {
    free: Vec<Resources>,
    used: Resources,
}

/// A recorded placement of `n` identical components across machines;
/// releasable via [`Cluster::release`].
#[derive(Clone, Debug, Default)]
pub struct Placement {
    pub res: Resources,
    /// (machine index, component count) pairs.
    pub by_machine: Vec<(u32, u32)>,
}

impl Placement {
    pub fn count(&self) -> u32 {
        self.by_machine.iter().map(|&(_, k)| k).sum()
    }
}

/// The cluster: a vector of machines (uniform in the paper's simulations:
/// 100 × (32 cores, 128 GB), §4.1).
///
/// `used` is tracked incrementally — `used()` is O(1), it is read on every
/// simulator event for the allocation metrics (§Perf).
#[derive(Clone, Debug)]
pub struct Cluster {
    machines: Vec<Machine>,
    used: Resources,
    total: Resources,
}

impl Cluster {
    pub fn new(machines: Vec<Machine>) -> Self {
        assert!(!machines.is_empty());
        let mut total = Resources::ZERO;
        for m in &machines {
            total.add(&m.total);
        }
        Cluster {
            machines,
            used: Resources::ZERO,
            total,
        }
    }

    /// `n` identical machines.
    pub fn uniform(n: usize, per_machine: Resources) -> Self {
        Cluster::new(vec![Machine::new(per_machine); n])
    }

    /// The paper's simulated cluster: 100 machines × 32 cores × 128 GB.
    pub fn paper_sim() -> Self {
        Cluster::uniform(100, Resources::new(32.0, 128.0 * 1024.0))
    }

    /// A single abstract machine of `units` 1-CPU units — the 1-D model of
    /// the illustrative example (Fig. 1).
    pub fn units(units: u32) -> Self {
        Cluster::uniform(1, Resources::new(units as f64, units as f64))
    }

    pub fn n_machines(&self) -> usize {
        self.machines.len()
    }

    pub fn machines(&self) -> &[Machine] {
        &self.machines
    }

    /// Reset all machines to empty (start of a virtual-assignment pass).
    pub fn clear(&mut self) {
        for m in &mut self.machines {
            m.free = m.total;
        }
        self.used = Resources::ZERO;
    }

    /// Aggregate capacity (O(1), cached).
    pub fn total(&self) -> Resources {
        self.total
    }

    /// Quick reject: can even one component of `res` fit *anywhere*?
    /// (Aggregate check — machine scan only happens when it might.)
    #[inline]
    fn aggregate_can_fit_one(&self, res: &Resources) -> bool {
        let free_cpu = self.total.cpu - self.used.cpu;
        let free_ram = self.total.ram_mb - self.used.ram_mb;
        res.cpu <= free_cpu + 1e-9 && res.ram_mb <= free_ram + 1e-9
    }

    /// Aggregate currently-used resources (O(1), tracked incrementally).
    pub fn used(&self) -> Resources {
        self.used
    }

    /// How many components of `res` fit cluster-wide right now.
    pub fn fit_count(&self, res: &Resources) -> u64 {
        if !self.aggregate_can_fit_one(res) {
            return 0;
        }
        self.machines
            .iter()
            .map(|m| m.fit_count(res) as u64)
            .sum()
    }

    /// Place up to `n` components of `res`, greedily filling machines in
    /// order. Returns how many were placed.
    pub fn place_up_to(&mut self, res: &Resources, n: u32) -> u32 {
        if n == 0 || !self.aggregate_can_fit_one(res) {
            return 0;
        }
        let mut left = n;
        for m in &mut self.machines {
            if left == 0 {
                break;
            }
            let k = m.fit_count(res).min(left);
            if k > 0 {
                m.free.sub(&res.scaled(k as f64));
                left -= k;
            }
        }
        let placed = n - left;
        self.used.add(&res.scaled(placed as f64));
        placed
    }

    /// All-or-nothing placement of `n` components of `res`.
    /// Two-pass: count feasibility first, then commit.
    pub fn place_all(&mut self, res: &Resources, n: u32) -> bool {
        if self.fit_count(res) < n as u64 {
            return false;
        }
        let placed = self.place_up_to(res, n);
        debug_assert_eq!(placed, n);
        true
    }

    /// Place up to `n` components of `res`, recording which machines got
    /// how many — so the placement can later be released exactly
    /// (persistent-placement schedulers, e.g. the rigid baseline, and the
    /// Zoe back-end).
    pub fn place_up_to_tracked(&mut self, res: &Resources, n: u32) -> (u32, Placement) {
        if n == 0 || !self.aggregate_can_fit_one(res) {
            return (0, Placement { res: *res, by_machine: Vec::new() });
        }
        let mut left = n;
        let mut by_machine = Vec::with_capacity(4);
        for (i, m) in self.machines.iter_mut().enumerate() {
            if left == 0 {
                break;
            }
            let k = m.fit_count(res).min(left);
            if k > 0 {
                m.free.sub(&res.scaled(k as f64));
                left -= k;
                by_machine.push((i as u32, k));
            }
        }
        let placed = n - left;
        self.used.add(&res.scaled(placed as f64));
        (
            placed,
            Placement {
                res: *res,
                by_machine,
            },
        )
    }

    /// All-or-nothing tracked placement.
    pub fn place_all_tracked(&mut self, res: &Resources, n: u32) -> Option<Placement> {
        if self.fit_count(res) < n as u64 {
            return None;
        }
        let (placed, p) = self.place_up_to_tracked(res, n);
        debug_assert_eq!(placed, n);
        Some(p)
    }

    /// Release a tracked placement.
    pub fn release(&mut self, p: &Placement) {
        let mut released = 0u32;
        for &(mi, k) in &p.by_machine {
            let m = &mut self.machines[mi as usize];
            m.free.add(&p.res.scaled(k as f64));
            released += k;
            debug_assert!(m.free.cpu <= m.total.cpu + 1e-6);
            debug_assert!(m.free.ram_mb <= m.total.ram_mb + 1e-3);
        }
        self.used.sub(&p.res.scaled(released as f64));
    }

    /// Snapshot of the free vectors (and used total), for trial
    /// placements.
    pub fn save(&self) -> Snapshot {
        Snapshot {
            free: self.machines.iter().map(|m| m.free).collect(),
            used: self.used,
        }
    }

    /// Restore a snapshot taken with [`Cluster::save`].
    pub fn restore(&mut self, snap: &Snapshot) {
        debug_assert_eq!(snap.free.len(), self.machines.len());
        for (m, f) in self.machines.iter_mut().zip(&snap.free) {
            m.free = *f;
        }
        self.used = snap.used;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_cluster_counts() {
        let c = Cluster::units(10);
        assert_eq!(c.fit_count(&Resources::new(1.0, 1.0)), 10);
        assert_eq!(c.total().cpu, 10.0);
    }

    #[test]
    fn place_up_to_partial() {
        let mut c = Cluster::units(10);
        let unit = Resources::new(1.0, 1.0);
        assert_eq!(c.place_up_to(&unit, 7), 7);
        assert_eq!(c.place_up_to(&unit, 7), 3);
        assert_eq!(c.place_up_to(&unit, 7), 0);
        assert_eq!(c.used().cpu, 10.0);
    }

    #[test]
    fn place_all_is_transactional() {
        let mut c = Cluster::units(10);
        let unit = Resources::new(1.0, 1.0);
        assert!(c.place_all(&unit, 10));
        assert!(!c.place_all(&unit, 1));
        c.clear();
        assert!(!c.place_all(&unit, 11));
        // failed place_all must not consume anything
        assert_eq!(c.used().cpu, 0.0);
    }

    #[test]
    fn two_dimensional_fit() {
        // Machine with plenty CPU but tight RAM.
        let mut c = Cluster::uniform(1, Resources::new(32.0, 4096.0));
        let comp = Resources::new(1.0, 2048.0);
        assert_eq!(c.fit_count(&comp), 2);
        assert_eq!(c.place_up_to(&comp, 5), 2);
    }

    #[test]
    fn fragmentation_across_machines() {
        // 2 machines × 4 cores; a 5-core component fits nowhere even though
        // aggregate capacity is 8.
        let c = Cluster::uniform(2, Resources::new(4.0, 1e6));
        assert_eq!(c.fit_count(&Resources::new(5.0, 1.0)), 0);
        assert_eq!(c.fit_count(&Resources::new(2.0, 1.0)), 4);
    }

    #[test]
    fn save_restore() {
        let mut c = Cluster::units(10);
        let unit = Resources::new(1.0, 1.0);
        c.place_up_to(&unit, 4);
        let snap = c.save();
        c.place_up_to(&unit, 6);
        assert_eq!(c.used().cpu, 10.0);
        c.restore(&snap);
        assert_eq!(c.used().cpu, 4.0);
    }

    #[test]
    fn zero_resource_component_fits_infinitely() {
        let c = Cluster::units(1);
        assert!(c.fit_count(&Resources::ZERO) > 1_000_000);
    }
}

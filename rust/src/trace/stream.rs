//! Streaming trace replay: a [`TraceStream`] yields requests one at a
//! time from a JSONL trace (native app traces and recorded event logs),
//! so the simulation engine can replay traces **far larger than memory**
//! — the engine holds one pending arrival plus the O(active) request
//! slab, never the whole trace.
//!
//! The price of not materializing is that the stream cannot sort:
//! arrivals must already be non-decreasing in time (true for recorded
//! event logs by construction, and for most production traces). An
//! out-of-order arrival yields a [`TraceError`] naming the line — the
//! materialized [`TraceSource`] path (which sorts) is the fallback for
//! unsorted traces. CSV traces cannot stream at all: ClusterData2011
//! ingestion aggregates task rows *per job*, which requires the whole
//! file; [`TraceStream::open`] rejects `.csv` paths with the same error
//! the CLI turns into exit 2.
//!
//! Consumed by [`crate::sim::Simulation::from_stream`] (single run) and
//! [`crate::sim::ExperimentPlan::from_trace_path`] (each grid task
//! re-opens and re-streams the file).

use std::io::BufRead;

use crate::core::{ReqId, Request};

use super::ingest::{parse_jsonl_line, IngestOptions, LineKind, TraceError, TraceSource};

/// A pull-based request source: `Iterator<Item = Result<Request,
/// TraceError>>` over an arrival-ordered trace, O(1) memory beyond the
/// current line. After yielding an error the stream is fused (further
/// `next()` calls return `None`).
pub struct TraceStream {
    inner: Inner,
    opts: IngestOptions,
    lineno: usize,
    last_arrival: f64,
    saw_meta: bool,
    saw_end: bool,
    emitted: u64,
    failed: bool,
}

enum Inner {
    /// Line-by-line JSONL reader (file, socket, in-memory cursor).
    Reader(Box<dyn BufRead>),
    /// An already-materialized (sorted, validated) request list — lets
    /// every consumer take the one stream type.
    List(std::vec::IntoIter<Request>),
}

impl TraceStream {
    fn new(inner: Inner, opts: IngestOptions) -> Self {
        TraceStream {
            inner,
            opts,
            lineno: 0,
            last_arrival: f64::NEG_INFINITY,
            saw_meta: false,
            saw_end: false,
            emitted: 0,
            failed: false,
        }
    }

    /// Open `path` for streaming replay. JSONL only: a `.csv` path is
    /// rejected up front (per-job aggregation needs the whole file — see
    /// the module docs).
    pub fn open(path: &str, opts: &IngestOptions) -> Result<Self, TraceError> {
        let is_csv = path
            .rsplit('.')
            .next()
            .map(|e| e.eq_ignore_ascii_case("csv"))
            .unwrap_or(false);
        if is_csv {
            return Err(TraceError {
                line: 0,
                msg: format!(
                    "{path}: CSV traces aggregate task rows per job and cannot stream; \
                     ingest materialized (no streaming) or convert to JSONL"
                ),
            });
        }
        let f = std::fs::File::open(path).map_err(|e| TraceError {
            line: 0,
            msg: format!("cannot open {path}: {e}"),
        })?;
        Ok(Self::from_jsonl_reader(
            Box::new(std::io::BufReader::new(f)),
            opts,
        ))
    }

    /// A stream over any buffered JSONL reader.
    pub fn from_jsonl_reader(reader: Box<dyn BufRead>, opts: &IngestOptions) -> Self {
        Self::new(Inner::Reader(reader), opts.clone())
    }

    /// A stream over an in-memory JSONL string (tests, recorded logs
    /// captured in a [`super::SharedBuf`]).
    pub fn from_jsonl_str(s: &str, opts: &IngestOptions) -> Self {
        Self::from_jsonl_reader(
            Box::new(std::io::Cursor::new(s.as_bytes().to_vec())),
            opts,
        )
    }

    /// Requests yielded so far.
    pub fn emitted(&self) -> u64 {
        self.emitted
    }
}

impl TraceSource {
    /// Consume this (already sorted and validated) source into a stream
    /// — the uniform input type of the streaming engine.
    pub fn into_stream(self) -> TraceStream {
        TraceStream::new(
            Inner::List(self.into_requests().into_iter()),
            IngestOptions::default(),
        )
    }
}

impl Iterator for TraceStream {
    type Item = Result<Request, TraceError>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.failed {
            return None;
        }
        let rd = match &mut self.inner {
            Inner::List(it) => {
                // Already sorted/validated/id-stamped by TraceSource;
                // only the emitted count needs maintaining here.
                let next = it.next();
                if next.is_some() {
                    self.emitted += 1;
                }
                return next.map(Ok);
            }
            Inner::Reader(rd) => rd,
        };
        let mut line = String::new();
        loop {
            line.clear();
            self.lineno += 1;
            match rd.read_line(&mut line) {
                Err(e) => {
                    self.failed = true;
                    return Some(Err(TraceError {
                        line: self.lineno,
                        msg: format!("io error: {e}"),
                    }));
                }
                Ok(0) => {
                    // EOF: a recorder log whose `end` line never made it
                    // to disk is a truncated recording — replaying only
                    // the arrivals that survived would simulate a
                    // different (shorter) workload than was recorded.
                    if self.saw_meta && !self.saw_end {
                        self.failed = true;
                        return Some(Err(TraceError {
                            line: 0,
                            msg: "event log has a `meta` line but no `end` line — the \
                                  recording is incomplete (truncated, or the run is \
                                  still in progress)"
                                .to_string(),
                        }));
                    }
                    return None;
                }
                Ok(_) => {}
            }
            match parse_jsonl_line(&line, self.lineno, &self.opts) {
                Err(e) => {
                    self.failed = true;
                    return Some(Err(e));
                }
                Ok(LineKind::Skip) => continue,
                Ok(LineKind::Meta) => {
                    self.saw_meta = true;
                    continue;
                }
                Ok(LineKind::End) => {
                    self.saw_end = true;
                    continue;
                }
                Ok(LineKind::App(mut req)) => {
                    if req.arrival < self.last_arrival {
                        self.failed = true;
                        return Some(Err(TraceError {
                            line: self.lineno,
                            msg: format!(
                                "streaming replay requires arrival-ordered traces: \
                                 arrival {} after {} — ingest materialized (which \
                                 sorts) instead",
                                req.arrival, self.last_arrival
                            ),
                        }));
                    }
                    self.last_arrival = req.arrival;
                    // Placeholder handle; the engine's request table
                    // assigns the real generational id at allocation.
                    req.id = ReqId::from(self.emitted as u32);
                    self.emitted += 1;
                    return Some(Ok(req));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const L1: &str =
        r#"{"arrival":1.0,"runtime":10.0,"n_core":1,"core_cpu":1.0,"core_ram_mb":64}"#;
    const L2: &str =
        r#"{"arrival":2.0,"runtime":10.0,"n_core":1,"core_cpu":1.0,"core_ram_mb":64}"#;

    #[test]
    fn streams_sorted_jsonl_one_request_at_a_time() {
        let s = format!("# c\n{L1}\n\n{L2}\n");
        let mut stream = TraceStream::from_jsonl_str(&s, &IngestOptions::default());
        let a = stream.next().unwrap().unwrap();
        assert_eq!(a.arrival, 1.0);
        let b = stream.next().unwrap().unwrap();
        assert_eq!(b.arrival, 2.0);
        assert!(stream.next().is_none());
        assert_eq!(stream.emitted(), 2);
    }

    #[test]
    fn out_of_order_arrivals_error_with_line_number() {
        let s = format!("{L2}\n{L1}\n");
        let mut stream = TraceStream::from_jsonl_str(&s, &IngestOptions::default());
        assert!(stream.next().unwrap().is_ok());
        let err = stream.next().unwrap().unwrap_err();
        assert_eq!(err.line, 2);
        assert!(err.msg.contains("arrival-ordered"), "{}", err.msg);
        assert!(stream.next().is_none(), "stream is fused after an error");
    }

    #[test]
    fn truncated_event_log_errors_at_eof() {
        let s = format!("{{\"ev\":\"meta\",\"schema\":2}}\n{L1}\n");
        let mut stream = TraceStream::from_jsonl_str(&s, &IngestOptions::default());
        assert!(stream.next().unwrap().is_ok());
        let err = stream.next().unwrap().unwrap_err();
        assert!(err.msg.contains("incomplete"), "{}", err.msg);
    }

    #[test]
    fn list_backed_streams_count_emitted() {
        let src = TraceSource::new(vec![crate::core::unit_request(0, 0.0, 1.0, 1, 0)]);
        let mut stream = src.into_stream();
        assert!(stream.next().unwrap().is_ok());
        assert!(stream.next().is_none());
        assert_eq!(stream.emitted(), 1);
    }

    #[test]
    fn csv_paths_are_rejected() {
        let err = TraceStream::open("whatever.csv", &IngestOptions::default()).unwrap_err();
        assert!(err.msg.contains("cannot stream"), "{}", err.msg);
    }
}

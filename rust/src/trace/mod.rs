//! Real-trace pipeline (§4.1): ingest, replay, record, and calibrate.
//!
//! The paper's evaluation is "trace-driven simulation with large-scale
//! real system traces"; the synthetic generator in [`crate::workload`]
//! only *approximates* such traces through parametric CDFs. This module
//! makes recorded executions a first-class input with four capabilities:
//!
//! * **Ingest** ([`TraceSource`]) — zero-dependency streaming parsers for
//!   two formats:
//!   - the **native JSONL app trace**: one JSON object per line with the
//!     request tuple (`arrival`, `runtime`, `n_core`, `core_cpu`,
//!     `core_ram_mb`, optional `n_elastic`/`elastic_cpu`/
//!     `elastic_ram_mb`/`class`/`priority`/`deadline`). Application
//!     class is inferred when absent (`n_elastic == 0` ⇒ B-R, else
//!     B-E); `deadline` is seconds relative to arrival (absent = none);
//!   - a **Google ClusterData2011-shaped CSV** (`task_events`-like
//!     columns: timestamp µs, —, job id, task index, —, event type, —,
//!     scheduling class, priority, CPU request, RAM request, …). Task
//!     rows are aggregated per job: distinct submitted task indices
//!     become components, the SCHEDULE→last-end span becomes the
//!     isolated runtime, and the scheduling class drives rigid/elastic
//!     inference (class 3 ⇒ interactive, class 2 ⇒ rigid batch,
//!     0/1 ⇒ elastic batch with one core "driver" component);
//!   - a **ClusterData2011-shaped `machine_events` CSV**
//!     ([`MachineEvents`]): exactly 6 columns (timestamp µs, machine id,
//!     event type 0=ADD/1=REMOVE/2=UPDATE, platform, CPU, RAM) turned
//!     into the time-0 machine population plus timestamped
//!     [`crate::pool::ClusterEvent`] churn — the same event type the
//!     synthetic [`crate::sim::FaultSpec`] generator emits, so real and
//!     synthetic failures drive one engine path (`--machine-events` /
//!     `--mtbf` on the CLI).
//!
//!   Both formats pass through the same schedulability caps
//!   ([`crate::workload::Caps`]) the synthetic generator enforces, so an
//!   ingested request can never deadlock a scheduler. Event-log
//!   `arrival` lines are exempt from capping — they record requests a
//!   simulation actually ran, which is what keeps record → replay
//!   bit-identical even for runs recorded with capping disabled.
//! * **Replay** — a [`TraceSource`] normalizes its requests (sorted by
//!   arrival, placeholder ids reassigned by the engine's request slab)
//!   and drives [`crate::sim::Simulation`] directly
//!   ([`TraceSource::simulate`]) or fans out over scheduler/policy
//!   configurations through [`crate::sim::ExperimentPlan::from_trace`];
//!   every scheduler, policy and metric works unchanged on real traces.
//! * **Streaming replay** ([`TraceStream`]) — arrival-ordered JSONL
//!   traces replay without being materialized at all: the engine pulls
//!   one request at a time ([`crate::sim::Simulation::from_stream`],
//!   [`crate::sim::ExperimentPlan::from_trace_path`]), so a trace 10×,
//!   100×, any multiple of RAM replays at O(active) memory. Out-of-order
//!   arrivals and truncated recordings yield [`TraceError`]s; CSV cannot
//!   stream (per-job aggregation needs the whole file) and is rejected
//!   up front.
//! * **Record** ([`TraceRecorder`]) — a hook in the simulation engine
//!   ([`crate::sim::Simulation::with_recorder`]) that emits a JSONL
//!   event log (`meta`, `arrival`, `alloc`, `rebalance`, `departure`,
//!   `end` lines) from any run. Arrival lines carry the full request
//!   tuple, so a recorded log is itself a valid trace:
//!   record → ingest → replay reproduces the original [`crate::sim::SimResult`]
//!   **bit-identically** (asserted in `rust/tests/trace_roundtrip.rs`).
//! * **Calibrate** ([`fit_workload`]) — extract per-metric quantiles
//!   from an ingested trace into piecewise-linear
//!   [`crate::util::dist::Empirical`] CDFs and assemble a
//!   [`crate::workload::WorkloadSpec`], closing the loop between real
//!   traces and the synthetic generator (fitted 10/50/90th quantiles
//!   match the trace's empirical quantiles to well under 5 %).
//!
//! The CLI front-end is `zoe trace {stats,replay,record,fit}`; a small
//! bundled sample lives at `rust/tests/data/sample_trace.jsonl`.
//!
//! ```no_run
//! use zoe::policy::Policy;
//! use zoe::pool::Cluster;
//! use zoe::sched::SchedKind;
//! use zoe::trace::{IngestOptions, TraceSource};
//!
//! let trace = TraceSource::from_path("cluster.jsonl", &IngestOptions::default()).unwrap();
//! let result = trace.simulate(Cluster::paper_sim(), Policy::sjf(), SchedKind::Flexible);
//! ```

mod fit;
mod ingest;
mod record;
mod stream;

pub use fit::*;
pub use ingest::*;
pub use record::*;
pub use stream::*;

//! Trace ingestion: streaming parsers for the native JSONL app-trace
//! format (and recorded event logs, whose `arrival` lines carry the same
//! fields) and for Google ClusterData2011-shaped `task_events` CSVs,
//! plus [`TraceSource`], the normalized replayable request list.

use std::collections::{BTreeMap, HashSet};
use std::io::BufRead;

use crate::core::{AppClass, ReqId, Request, Resources};
use crate::policy::Policy;
use crate::pool::{Cluster, ClusterEvent, ClusterEventKind, Machine};
use crate::sched::SchedSpec;
use crate::sim::{SimResult, Simulation};
use crate::util::json::Json;
use crate::workload::Caps;

/// A trace-parse failure, with the 1-based line it occurred at
/// (line 0 = file-level, e.g. the file could not be opened).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceError {
    /// 1-based line number of the failure (0 for file-level errors).
    pub line: usize,
    /// Human-readable description.
    pub msg: String,
}

impl std::fmt::Display for TraceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.line == 0 {
            write!(f, "trace error: {}", self.msg)
        } else {
            write!(f, "trace error at line {}: {}", self.line, self.msg)
        }
    }
}

impl std::error::Error for TraceError {}

/// Knobs for trace ingestion.
#[derive(Clone, Debug)]
pub struct IngestOptions {
    /// Schedulability caps applied to every ingested request (`None`
    /// disables capping — only safe when the trace is known to fit the
    /// target cluster). Defaults to [`Caps::paper`], the same caps the
    /// synthetic generator enforces. Event-log `arrival` lines are
    /// always exempt: they record requests a simulation actually ran,
    /// and re-capping them could alter the replay.
    pub caps: Option<Caps>,
    /// CSV only: Google traces normalize CPU requests to the largest
    /// machine; this scale converts them to cores (default 32.0, the
    /// paper's per-machine core count).
    pub cpu_scale: f64,
    /// CSV only: RAM counterpart of `cpu_scale`, in MB (default
    /// 128 GB = 131 072 MB, the paper's per-machine RAM).
    pub ram_scale_mb: f64,
}

impl Default for IngestOptions {
    fn default() -> Self {
        IngestOptions {
            caps: Some(Caps::paper()),
            cpu_scale: 32.0,
            ram_scale_mb: 128.0 * 1024.0,
        }
    }
}

/// A normalized, replayable request list ingested from a trace:
/// requests are sorted by arrival time (stable, so equal-arrival order
/// is the input order) and re-assigned dense ids `0..n` — the invariant
/// the simulator's request table indexes by.
#[derive(Clone, Debug)]
pub struct TraceSource {
    requests: Vec<Request>,
    /// Jobs dropped during CSV aggregation (no submit/end event, or a
    /// non-positive derived runtime). Always 0 for JSONL ingests, which
    /// reject bad lines with a [`TraceError`] instead.
    pub skipped: usize,
}

impl TraceSource {
    /// Normalize an explicit request list into a trace source.
    ///
    /// # Panics
    ///
    /// Panics when a request is invalid (non-finite arrival,
    /// non-positive runtime, or zero core components) — the parsing
    /// constructors validate per line and return [`TraceError`] instead.
    pub fn new(requests: Vec<Request>) -> Self {
        for r in &requests {
            assert!(r.arrival.is_finite(), "request arrival must be finite");
            assert!(r.runtime > 0.0, "request runtime must be positive");
            assert!(r.n_core >= 1, "a request needs at least one core component");
        }
        let mut requests = requests;
        requests.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        for (i, r) in requests.iter_mut().enumerate() {
            // Placeholder handles in arrival order; the engine's request
            // table assigns the real generational ids at allocation.
            r.id = ReqId::from(i as u32);
        }
        TraceSource { requests, skipped: 0 }
    }

    /// The normalized requests, sorted by arrival, ids dense `0..n`.
    pub fn requests(&self) -> &[Request] {
        &self.requests
    }

    /// Number of applications in the trace.
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    /// Whether the trace contains no applications.
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Arrival span (last − first arrival) in seconds; 0 for traces with
    /// fewer than two applications.
    pub fn span(&self) -> f64 {
        match (self.requests.first(), self.requests.last()) {
            (Some(a), Some(b)) => b.arrival - a.arrival,
            _ => 0.0,
        }
    }

    /// Consume the source, yielding the normalized request list (the
    /// form [`crate::sim::Simulation::new`] takes).
    pub fn into_requests(self) -> Vec<Request> {
        self.requests
    }

    /// Build a [`Simulation`] replaying this trace (attach a recorder
    /// with [`Simulation::with_recorder`] before running, if desired).
    pub fn simulation(
        &self,
        cluster: Cluster,
        policy: Policy,
        sched: impl Into<SchedSpec>,
    ) -> Simulation {
        Simulation::new(self.requests.clone(), cluster, policy, sched)
    }

    /// Replay the trace to completion under one configuration.
    pub fn simulate(
        &self,
        cluster: Cluster,
        policy: Policy,
        sched: impl Into<SchedSpec>,
    ) -> SimResult {
        self.simulation(cluster, policy, sched).run()
    }

    // ---- parsing constructors --------------------------------------------

    /// Ingest a trace file, auto-detecting the format from the
    /// extension: `.csv` parses as ClusterData2011-shaped CSV, anything
    /// else as JSONL (app traces and recorded event logs).
    pub fn from_path(path: &str, opts: &IngestOptions) -> Result<Self, TraceError> {
        let is_csv = path
            .rsplit('.')
            .next()
            .map(|e| e.eq_ignore_ascii_case("csv"))
            .unwrap_or(false);
        if is_csv {
            Self::from_csv_path(path, opts)
        } else {
            Self::from_jsonl_path(path, opts)
        }
    }

    /// Ingest a JSONL file (native app trace or recorded event log).
    pub fn from_jsonl_path(path: &str, opts: &IngestOptions) -> Result<Self, TraceError> {
        let f = std::fs::File::open(path).map_err(|e| TraceError {
            line: 0,
            msg: format!("cannot open {path}: {e}"),
        })?;
        Self::from_jsonl_reader(std::io::BufReader::new(f), opts)
    }

    /// Ingest a ClusterData2011-shaped CSV file.
    pub fn from_csv_path(path: &str, opts: &IngestOptions) -> Result<Self, TraceError> {
        let f = std::fs::File::open(path).map_err(|e| TraceError {
            line: 0,
            msg: format!("cannot open {path}: {e}"),
        })?;
        Self::from_csv_reader(std::io::BufReader::new(f), opts)
    }

    /// Ingest JSONL from an in-memory string.
    pub fn from_jsonl_str(s: &str, opts: &IngestOptions) -> Result<Self, TraceError> {
        Self::from_jsonl_reader(s.as_bytes(), opts)
    }

    /// Ingest CSV from an in-memory string.
    pub fn from_csv_str(s: &str, opts: &IngestOptions) -> Result<Self, TraceError> {
        Self::from_csv_reader(s.as_bytes(), opts)
    }

    /// Streaming JSONL ingest: one line at a time, O(line) memory beyond
    /// the accumulated requests. Lines that are empty or start with `#`
    /// are skipped; event-log lines other than `arrival` are skipped;
    /// anything else must be a valid app object. A file that opens with
    /// a recorder `meta` line but never reaches its `end` line is a
    /// truncated recording and is rejected — silently replaying only the
    /// arrivals that made it to disk would simulate a different
    /// (shorter) workload than the one recorded.
    pub fn from_jsonl_reader<R: BufRead>(r: R, opts: &IngestOptions) -> Result<Self, TraceError> {
        let mut requests = Vec::new();
        let mut lineno = 0usize;
        let (mut saw_meta, mut saw_end) = (false, false);
        for line in r.lines() {
            lineno += 1;
            let line = line.map_err(|e| TraceError {
                line: lineno,
                msg: format!("io error: {e}"),
            })?;
            match parse_jsonl_line(&line, lineno, opts)? {
                LineKind::Skip => {}
                LineKind::Meta => saw_meta = true,
                LineKind::End => saw_end = true,
                LineKind::App(req) => requests.push(req),
            }
        }
        if saw_meta && !saw_end {
            return Err(TraceError {
                line: 0,
                msg: "event log has a `meta` line but no `end` line — the recording is \
                      incomplete (truncated, or the run is still in progress)"
                    .to_string(),
            });
        }
        Ok(TraceSource::new(requests))
    }

    /// Streaming CSV ingest with per-job aggregation (see the module
    /// docs of [`crate::trace`] for the column shape and the
    /// rigid/elastic inference rules).
    pub fn from_csv_reader<R: BufRead>(r: R, opts: &IngestOptions) -> Result<Self, TraceError> {
        let mut jobs: BTreeMap<u64, JobAgg> = BTreeMap::new();
        let mut lineno = 0usize;
        for line in r.lines() {
            lineno += 1;
            let line = line.map_err(|e| TraceError {
                line: lineno,
                msg: format!("io error: {e}"),
            })?;
            parse_csv_line(&line, lineno, &mut jobs)?;
        }
        Ok(build_csv_jobs(&jobs, opts))
    }
}

/// Serialize a request as the flat key/value pairs of the native JSONL
/// app-trace format (shared with the recorder's `arrival` lines, which
/// prepend their own identity fields — `id` = submission seq, plus the
/// generational `slot`/`gen`; ingest ignores all three). Numbers
/// round-trip exactly: the JSON writer emits shortest-roundtrip floats,
/// which is what makes record → replay bit-identical.
pub(crate) fn request_to_json_fields(r: &Request) -> Vec<(&'static str, Json)> {
    let mut fields = vec![
        ("class", Json::str(r.class.label())),
        ("arrival", Json::num(r.arrival)),
        ("runtime", Json::num(r.runtime)),
        ("n_core", Json::num(r.n_core as f64)),
        ("core_cpu", Json::num(r.core_res.cpu)),
        ("core_ram_mb", Json::num(r.core_res.ram_mb)),
        ("n_elastic", Json::num(r.n_elastic as f64)),
        ("elastic_cpu", Json::num(r.elastic_res.cpu)),
        ("elastic_ram_mb", Json::num(r.elastic_res.ram_mb)),
        ("priority", Json::num(r.priority)),
    ];
    // Optional column, emitted only when set: recordings of
    // deadline-free runs stay byte-identical to the pre-deadline format.
    if r.deadline.is_finite() {
        fields.push(("deadline", Json::num(r.deadline)));
    }
    fields
}

/// What one JSONL line turned out to be.
pub(crate) enum LineKind {
    /// Blank, comment, or an event-log record with no request payload
    /// (`alloc` / `rebalance` / `departure`).
    Skip,
    /// A recorder `meta` line (start-of-log marker).
    Meta,
    /// A recorder `end` line (complete-log marker).
    End,
    /// An application, from an app-trace line or an event-log arrival.
    App(Request),
}

/// Parse one JSONL line (see [`LineKind`] for the outcomes). Shared by
/// the materialized ingest and the streaming [`super::TraceStream`].
pub(crate) fn parse_jsonl_line(
    line: &str,
    lineno: usize,
    opts: &IngestOptions,
) -> Result<LineKind, TraceError> {
    let t = line.trim();
    if t.is_empty() || t.starts_with('#') {
        return Ok(LineKind::Skip);
    }
    let j = Json::parse(t).map_err(|e| TraceError {
        line: lineno,
        msg: e.to_string(),
    })?;
    let ev = j.get("ev");
    let from_event_log = !ev.is_null();
    if from_event_log {
        match ev.as_str() {
            Some("arrival") => {} // event-log arrivals carry the full app tuple
            Some("meta") => return Ok(LineKind::Meta),
            Some("end") => return Ok(LineKind::End),
            Some(_) => return Ok(LineKind::Skip), // alloc / rebalance / departure
            None => {
                return Err(TraceError {
                    line: lineno,
                    msg: "\"ev\" must be a string".to_string(),
                })
            }
        }
    }
    // Event-log arrivals record requests a simulation *actually ran* —
    // re-capping them could alter the replay, so they are exempt; only
    // plain app-trace lines (foreign traces) pass through the caps.
    // This is what makes record → ingest → replay bit-identical even
    // for runs recorded with capping disabled.
    request_from_json(&j, lineno, opts, from_event_log).map(LineKind::App)
}

/// Decode an app object (or event-log `arrival` record) into a request.
/// `exempt_caps` skips the schedulability caps (event-log arrivals).
fn request_from_json(
    j: &Json,
    line: usize,
    opts: &IngestOptions,
    exempt_caps: bool,
) -> Result<Request, TraceError> {
    let err = |msg: String| TraceError { line, msg };
    let num = |key: &str| -> Result<f64, TraceError> {
        j.get(key)
            .as_f64()
            .ok_or_else(|| err(format!("missing or non-numeric field \"{key}\"")))
    };
    let arrival = j
        .get("arrival")
        .as_f64()
        .or_else(|| j.get("t").as_f64())
        .ok_or_else(|| err("missing or non-numeric field \"arrival\"".to_string()))?;
    let runtime = num("runtime")?;
    let n_core = j
        .get("n_core")
        .as_u64()
        .ok_or_else(|| err("missing or non-integer field \"n_core\"".to_string()))?
        as u32;
    let core_cpu = num("core_cpu")?;
    let core_ram_mb = num("core_ram_mb")?;
    let n_elastic = {
        let v = j.get("n_elastic");
        if v.is_null() {
            0
        } else {
            v.as_u64()
                .ok_or_else(|| err("\"n_elastic\" must be a non-negative integer".to_string()))?
                as u32
        }
    };
    let (elastic_cpu, elastic_ram_mb) = if n_elastic > 0 {
        (num("elastic_cpu")?, num("elastic_ram_mb")?)
    } else {
        (
            j.get("elastic_cpu").as_f64().unwrap_or(0.0),
            j.get("elastic_ram_mb").as_f64().unwrap_or(0.0),
        )
    };
    let priority = j.get("priority").as_f64().unwrap_or(0.0);
    let deadline = {
        let v = j.get("deadline");
        if v.is_null() {
            f64::INFINITY
        } else {
            let d = v
                .as_f64()
                .ok_or_else(|| err("\"deadline\" must be a number".to_string()))?;
            if !(d > 0.0) || !d.is_finite() {
                return Err(err(format!("deadline must be positive and finite (got {d})")));
            }
            d
        }
    };
    let class = {
        let c = j.get("class");
        if c.is_null() {
            None
        } else {
            match c.as_str() {
                Some("B-E") => Some(AppClass::BatchElastic),
                Some("B-R") => Some(AppClass::BatchRigid),
                Some("Int") => Some(AppClass::Interactive),
                _ => return Err(err("\"class\" must be one of B-E|B-R|Int".to_string())),
            }
        }
    };
    if !arrival.is_finite() {
        return Err(err(format!("arrival must be finite (got {arrival})")));
    }
    if !runtime.is_finite() || runtime <= 0.0 {
        return Err(err(format!("runtime must be positive and finite (got {runtime})")));
    }
    if n_core < 1 {
        return Err(err("n_core must be >= 1".to_string()));
    }
    for (name, v) in [
        ("core_cpu", core_cpu),
        ("core_ram_mb", core_ram_mb),
        ("elastic_cpu", elastic_cpu),
        ("elastic_ram_mb", elastic_ram_mb),
        ("priority", priority),
    ] {
        if !v.is_finite() || v < 0.0 {
            return Err(err(format!("{name} must be non-negative and finite (got {v})")));
        }
    }
    let mut r = Request {
        id: ReqId::from(0), // reassigned at table allocation
        class: class.unwrap_or(if n_elastic == 0 {
            AppClass::BatchRigid
        } else {
            AppClass::BatchElastic
        }),
        arrival,
        runtime,
        n_core,
        core_res: Resources::new(core_cpu, core_ram_mb),
        n_elastic,
        elastic_res: Resources::new(elastic_cpu, elastic_ram_mb),
        priority,
        deadline,
    };
    if !exempt_caps {
        apply_caps(&mut r, opts);
    }
    Ok(r)
}

fn apply_caps(r: &mut Request, opts: &IngestOptions) {
    if let Some(caps) = &opts.caps {
        r.n_core = caps.cap_cores(r.n_core, &r.core_res);
        r.n_elastic = caps.cap_elastic(r.n_elastic, r.n_core, &r.core_res, &r.elastic_res);
    }
}

// ---------------------------------------------------------------------------
// ClusterData2011-shaped CSV
// ---------------------------------------------------------------------------

/// ClusterData2011 `task_events` event types this parser interprets.
const EV_SUBMIT: u32 = 0;
const EV_SCHEDULE: u32 = 1;
const EV_FAIL: u32 = 3;
const EV_FINISH: u32 = 4;
const EV_KILL: u32 = 5;
const EV_LOST: u32 = 6;

/// ClusterData2011 encodes events that happened *after* the trace
/// window with timestamp 2^63 − 1 µs. Rows at or beyond this sentinel
/// carry no usable time: interpreting one as a real end event would
/// give its job a ~292 000-year runtime. They are dropped, so a job
/// whose only end event is out-of-window is skipped like any other
/// unfinished job. (Timestamp 0 = "before the window" is kept: for
/// submits it degrades to "arrived at trace start".)
const CSV_TIME_SENTINEL_US: f64 = 9.0e18;

/// Per-job accumulator over task rows.
struct JobAgg {
    first_submit: f64,
    first_schedule: f64,
    last_end: f64,
    tasks: HashSet<u64>,
    cpu_sum: f64,
    ram_sum: f64,
    res_rows: u32,
    sched_class: u32,
    priority: f64,
}

fn parse_csv_line(
    line: &str,
    lineno: usize,
    jobs: &mut BTreeMap<u64, JobAgg>,
) -> Result<(), TraceError> {
    let t = line.trim();
    if t.is_empty() || t.starts_with('#') {
        return Ok(());
    }
    let cols: Vec<&str> = t.split(',').collect();
    if cols.len() < 6 {
        return Err(TraceError {
            line: lineno,
            msg: format!(
                "expected >= 6 comma-separated columns (task_events shape), got {}",
                cols.len()
            ),
        });
    }
    let time_us: f64 = cols[0].trim().parse().map_err(|_| TraceError {
        line: lineno,
        msg: format!("non-numeric timestamp \"{}\"", cols[0]),
    })?;
    if !(time_us < CSV_TIME_SENTINEL_US) || time_us < 0.0 {
        return Ok(()); // out-of-window sentinel (or garbage): no usable time
    }
    let job_id: u64 = cols[2].trim().parse().map_err(|_| TraceError {
        line: lineno,
        msg: format!("non-numeric job id \"{}\"", cols[2]),
    })?;
    let event: u32 = cols[5].trim().parse().map_err(|_| TraceError {
        line: lineno,
        msg: format!("non-numeric event type \"{}\"", cols[5]),
    })?;
    let sched_class: u32 = cols
        .get(7)
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(0);
    let priority: f64 = cols
        .get(8)
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(0.0);
    let cpu: Option<f64> = cols.get(9).and_then(|s| s.trim().parse().ok());
    let ram: Option<f64> = cols.get(10).and_then(|s| s.trim().parse().ok());
    let t_s = time_us * 1e-6;
    let agg = jobs.entry(job_id).or_insert_with(|| JobAgg {
        first_submit: f64::INFINITY,
        first_schedule: f64::INFINITY,
        last_end: f64::NEG_INFINITY,
        tasks: HashSet::new(),
        cpu_sum: 0.0,
        ram_sum: 0.0,
        res_rows: 0,
        sched_class: 0,
        priority: 0.0,
    });
    agg.sched_class = agg.sched_class.max(sched_class);
    agg.priority = agg.priority.max(priority);
    match event {
        EV_SUBMIT => {
            agg.first_submit = agg.first_submit.min(t_s);
            let task: u64 = cols
                .get(3)
                .and_then(|s| s.trim().parse().ok())
                .unwrap_or(0);
            agg.tasks.insert(task);
            if let (Some(c), Some(m)) = (cpu, ram) {
                agg.cpu_sum += c;
                agg.ram_sum += m;
                agg.res_rows += 1;
            }
        }
        EV_SCHEDULE => agg.first_schedule = agg.first_schedule.min(t_s),
        EV_FAIL | EV_FINISH | EV_KILL | EV_LOST => agg.last_end = agg.last_end.max(t_s),
        _ => {} // EVICT and attribute-update rows carry no lifecycle info we use
    }
    Ok(())
}

/// Turn the aggregated jobs into requests (deterministic: jobs iterate
/// in ascending job-id order, arrival ties keep that order through the
/// stable sort in `TraceSource::new`).
fn build_csv_jobs(jobs: &BTreeMap<u64, JobAgg>, opts: &IngestOptions) -> TraceSource {
    let mut t0 = f64::INFINITY;
    for a in jobs.values() {
        if a.first_submit < t0 {
            t0 = a.first_submit;
        }
    }
    let mut requests = Vec::new();
    let mut skipped = 0usize;
    for a in jobs.values() {
        if !a.first_submit.is_finite() {
            skipped += 1; // end/schedule rows only, submission lost
            continue;
        }
        let start = if a.first_schedule.is_finite() {
            a.first_schedule
        } else {
            a.first_submit
        };
        if !(a.last_end > start) {
            skipped += 1; // never finished (or zero-length): no runtime
            continue;
        }
        let runtime = a.last_end - start;
        let comps = a.tasks.len().max(1) as u32;
        let (cpu, ram_mb) = if a.res_rows > 0 {
            (
                a.cpu_sum / a.res_rows as f64 * opts.cpu_scale,
                a.ram_sum / a.res_rows as f64 * opts.ram_scale_mb,
            )
        } else {
            (1.0, 1024.0)
        };
        let res = Resources::new(cpu, ram_mb);
        // Rigid/elastic inference from the Google scheduling class:
        // 3 = latency-sensitive, human-facing → interactive;
        // 2 = production batch with strict shape → rigid (all core);
        // 0/1 = throughput analytics → elastic, Spark-like: one core
        // "driver" component, the remaining tasks elastic "executors".
        let (class, n_core, n_elastic, priority) = match a.sched_class {
            3 => (AppClass::Interactive, 1, comps - 1, a.priority),
            2 => (AppClass::BatchRigid, comps, 0, 0.0),
            _ => {
                if comps <= 1 {
                    (AppClass::BatchRigid, 1, 0, 0.0)
                } else {
                    (AppClass::BatchElastic, 1, comps - 1, 0.0)
                }
            }
        };
        let mut r = Request {
            id: ReqId::from(0),
            class,
            arrival: a.first_submit - t0,
            runtime,
            n_core,
            core_res: res,
            n_elastic,
            elastic_res: res,
            priority,
            deadline: f64::INFINITY,
        };
        apply_caps(&mut r, opts);
        requests.push(r);
    }
    let mut src = TraceSource::new(requests);
    src.skipped = skipped;
    src
}

// ---------------------------------------------------------------------------
// ClusterData2011-shaped machine_events CSV
// ---------------------------------------------------------------------------

/// `machine_events` event types (distinct numbering from `task_events`).
const MEV_ADD: u32 = 0;
const MEV_REMOVE: u32 = 1;
const MEV_UPDATE: u32 = 2;

/// A parsed ClusterData2011-shaped `machine_events` file: the machine
/// population (dense-indexed), which machines exist at time 0, and the
/// in-window churn as timestamped [`ClusterEvent`]s — the same event
/// type the synthetic [`crate::sim::FaultSpec`] generator emits, so real
/// and synthetic churn drive one engine path.
///
/// Every machine that ever appears is pre-registered at a dense index
/// (first-appearance order); machines that only join mid-trace start
/// *failed* (zero capacity) and their ADD becomes a restore. This keeps
/// machine indices stable for the whole run regardless of churn order.
#[derive(Clone, Debug, Default)]
pub struct MachineEvents {
    /// Nominal capacity of each machine (dense index), already scaled by
    /// [`IngestOptions::cpu_scale`] / `ram_scale_mb`.
    pub capacities: Vec<Resources>,
    /// Whether machine `i` is up at time 0.
    pub present: Vec<bool>,
    /// In-window churn (time > 0), ascending by time (stable: equal
    /// times keep file order).
    pub events: Vec<ClusterEvent>,
    /// Rows dropped: out-of-window sentinel or negative timestamps,
    /// REMOVE/UPDATE of a machine never added, ADD/UPDATE rows missing
    /// capacity columns.
    pub skipped: u64,
}

impl MachineEvents {
    /// Number of machines that ever appear in the file.
    pub fn n_machines(&self) -> usize {
        self.capacities.len()
    }

    /// Whether the file contained no machines at all.
    pub fn is_empty(&self) -> bool {
        self.capacities.is_empty()
    }

    /// The time-0 cluster: every machine registered at its dense index,
    /// with not-yet-present machines failed (zero capacity) so a later
    /// ADD restores them in place.
    pub fn initial_cluster(&self) -> Cluster {
        let machines = self.capacities.iter().map(|&r| Machine::new(r)).collect();
        let mut c = Cluster::new(machines);
        for (i, &up) in self.present.iter().enumerate() {
            if !up {
                c.fail_machine(i as u32);
            }
        }
        c
    }

    /// Parse a `machine_events` CSV file.
    pub fn from_csv_path(path: &str, opts: &IngestOptions) -> Result<Self, TraceError> {
        let f = std::fs::File::open(path).map_err(|e| TraceError {
            line: 0,
            msg: format!("cannot open {path}: {e}"),
        })?;
        Self::from_csv_reader(std::io::BufReader::new(f), opts)
    }

    /// Parse `machine_events` CSV from an in-memory string.
    pub fn from_csv_str(s: &str, opts: &IngestOptions) -> Result<Self, TraceError> {
        Self::from_csv_reader(s.as_bytes(), opts)
    }

    /// Streaming `machine_events` parse. Columns (exactly 6):
    /// `timestamp_us, machine_id, event_type, platform_id, cpu, ram`
    /// with event types 0 = ADD, 1 = REMOVE, 2 = UPDATE and capacities
    /// normalized to the largest machine (rescaled via `opts`).
    pub fn from_csv_reader<R: BufRead>(r: R, opts: &IngestOptions) -> Result<Self, TraceError> {
        let mut me = MachineEvents::default();
        let mut index: BTreeMap<u64, u32> = BTreeMap::new();
        let mut lineno = 0usize;
        for line in r.lines() {
            lineno += 1;
            let line = line.map_err(|e| TraceError {
                line: lineno,
                msg: format!("io error: {e}"),
            })?;
            parse_machine_event_line(&line, lineno, opts, &mut index, &mut me)?;
        }
        me.events.sort_by(|a, b| a.time.total_cmp(&b.time));
        Ok(me)
    }
}

fn parse_machine_event_line(
    line: &str,
    lineno: usize,
    opts: &IngestOptions,
    index: &mut BTreeMap<u64, u32>,
    me: &mut MachineEvents,
) -> Result<(), TraceError> {
    let t = line.trim();
    if t.is_empty() || t.starts_with('#') {
        return Ok(());
    }
    let cols: Vec<&str> = t.split(',').collect();
    if cols.len() != 6 {
        let hint = if cols.len() > 6 {
            " — this looks like a task_events file (>= 6 columns with job/task ids); \
             pass it via --trace, not --machine-events"
        } else {
            ""
        };
        return Err(TraceError {
            line: lineno,
            msg: format!(
                "expected exactly 6 comma-separated columns (machine_events shape: \
                 timestamp,machine_id,event_type,platform,cpu,ram), got {}{}",
                cols.len(),
                hint
            ),
        });
    }
    let time_us: f64 = cols[0].trim().parse().map_err(|_| TraceError {
        line: lineno,
        msg: format!("non-numeric timestamp \"{}\"", cols[0]),
    })?;
    if !(time_us < CSV_TIME_SENTINEL_US) || time_us < 0.0 {
        me.skipped += 1; // out-of-window sentinel (or garbage): no usable time
        return Ok(());
    }
    let machine_id: u64 = cols[1].trim().parse().map_err(|_| TraceError {
        line: lineno,
        msg: format!("non-numeric machine id \"{}\"", cols[1]),
    })?;
    let event: u32 = cols[2].trim().parse().map_err(|_| TraceError {
        line: lineno,
        msg: format!("non-numeric event type \"{}\"", cols[2]),
    })?;
    let res = {
        let cpu: Option<f64> = cols[4].trim().parse().ok();
        let ram: Option<f64> = cols[5].trim().parse().ok();
        match (cpu, ram) {
            (Some(c), Some(m)) if c >= 0.0 && m >= 0.0 && c.is_finite() && m.is_finite() => {
                Some(Resources::new(c * opts.cpu_scale, m * opts.ram_scale_mb))
            }
            _ => None,
        }
    };
    let time = time_us * 1e-6;
    match event {
        MEV_ADD => {
            let Some(res) = res else {
                me.skipped += 1; // ADD without a usable capacity
                return Ok(());
            };
            match index.get(&machine_id) {
                None => {
                    let idx = me.capacities.len() as u32;
                    index.insert(machine_id, idx);
                    me.capacities.push(res);
                    if time == 0.0 {
                        me.present.push(true);
                    } else {
                        // Joins mid-trace: starts failed, this ADD
                        // restores it.
                        me.present.push(false);
                        me.events.push(ClusterEvent {
                            time,
                            machine: idx,
                            kind: ClusterEventKind::Add(res),
                        });
                    }
                }
                Some(&idx) => {
                    // Re-ADD of a known machine: a restore after REMOVE.
                    me.capacities[idx as usize] = res;
                    if time == 0.0 {
                        me.present[idx as usize] = true;
                    } else {
                        me.events.push(ClusterEvent {
                            time,
                            machine: idx,
                            kind: ClusterEventKind::Add(res),
                        });
                    }
                }
            }
        }
        MEV_REMOVE => match index.get(&machine_id) {
            None => me.skipped += 1, // REMOVE of a machine never added
            Some(&idx) => {
                if time == 0.0 {
                    me.present[idx as usize] = false;
                } else {
                    me.events.push(ClusterEvent {
                        time,
                        machine: idx,
                        kind: ClusterEventKind::Remove,
                    });
                }
            }
        },
        MEV_UPDATE => match (index.get(&machine_id), res) {
            (Some(&idx), Some(res)) => {
                if time == 0.0 {
                    me.capacities[idx as usize] = res;
                } else {
                    me.events.push(ClusterEvent {
                        time,
                        machine: idx,
                        kind: ClusterEventKind::Update(res),
                    });
                }
            }
            _ => me.skipped += 1, // unknown machine or no usable capacity
        },
        _ => {
            return Err(TraceError {
                line: lineno,
                msg: format!("unknown machine_events event type {event} (expected 0|1|2)"),
            })
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line_req(line: &str) -> Request {
        let src = TraceSource::from_jsonl_str(line, &IngestOptions::default()).unwrap();
        src.requests()[0].clone()
    }

    #[test]
    fn jsonl_minimal_line_parses_with_inference() {
        let r = line_req(r#"{"arrival":5.0,"runtime":30.0,"n_core":2,"core_cpu":1.5,"core_ram_mb":2048}"#);
        assert_eq!(r.class, AppClass::BatchRigid); // no elastic ⇒ B-R
        assert_eq!(r.n_core, 2);
        assert_eq!(r.n_elastic, 0);
        assert_eq!(r.arrival, 5.0);
        assert_eq!(r.core_res.cpu, 1.5);
        let r = line_req(
            r#"{"arrival":0.0,"runtime":30.0,"n_core":1,"core_cpu":1.0,"core_ram_mb":64,"n_elastic":4,"elastic_cpu":0.5,"elastic_ram_mb":32}"#,
        );
        assert_eq!(r.class, AppClass::BatchElastic); // elastic ⇒ B-E
        assert_eq!(r.n_elastic, 4);
    }

    #[test]
    fn jsonl_skips_blanks_comments_and_non_arrival_events() {
        let s = "\n# comment\n{\"ev\":\"meta\",\"schema\":1}\n\
                 {\"ev\":\"alloc\",\"t\":1.0,\"id\":0,\"grant\":2}\n\
                 {\"arrival\":0.0,\"runtime\":10.0,\"n_core\":1,\"core_cpu\":1.0,\"core_ram_mb\":64}\n\
                 {\"ev\":\"end\",\"t\":10.0,\"events\":2}\n";
        let src = TraceSource::from_jsonl_str(s, &IngestOptions::default()).unwrap();
        assert_eq!(src.len(), 1);
    }

    #[test]
    fn truncated_event_log_is_rejected() {
        // A recorder log (meta line) whose end line never made it to
        // disk must not silently replay as a shorter workload.
        let s = "{\"ev\":\"meta\",\"schema\":1}\n\
                 {\"ev\":\"arrival\",\"t\":0.0,\"arrival\":0.0,\"runtime\":10.0,\"n_core\":1,\"core_cpu\":1.0,\"core_ram_mb\":64}\n";
        let err = TraceSource::from_jsonl_str(s, &IngestOptions::default()).unwrap_err();
        assert!(err.msg.contains("incomplete"), "{}", err.msg);
        // A plain app trace (no meta) needs no end marker.
        let s = "{\"arrival\":0.0,\"runtime\":10.0,\"n_core\":1,\"core_cpu\":1.0,\"core_ram_mb\":64}\n";
        assert!(TraceSource::from_jsonl_str(s, &IngestOptions::default()).is_ok());
    }

    #[test]
    fn jsonl_requires_elastic_resources_when_elastic() {
        let s = r#"{"arrival":0.0,"runtime":10.0,"n_core":1,"core_cpu":1.0,"core_ram_mb":64,"n_elastic":4}"#;
        let err = TraceSource::from_jsonl_str(s, &IngestOptions::default()).unwrap_err();
        assert_eq!(err.line, 1);
        assert!(err.msg.contains("elastic_cpu"), "{}", err.msg);
    }

    #[test]
    fn jsonl_request_round_trips_exactly() {
        let orig = crate::core::RequestBuilder::new(3)
            .arrival(12.345678901234567)
            .runtime(98.7654321)
            .cores(2, Resources::new(1.25, 3000.5))
            .elastics(7, Resources::new(0.5, 1024.0))
            .priority(1.0)
            .build();
        let j = Json::obj(request_to_json_fields(&orig));
        let mut opts = IngestOptions::default();
        opts.caps = None;
        let back = request_from_json(&j, 1, &opts, false).unwrap();
        assert_eq!(back.arrival.to_bits(), orig.arrival.to_bits());
        assert_eq!(back.runtime.to_bits(), orig.runtime.to_bits());
        assert_eq!(back.n_core, orig.n_core);
        assert_eq!(back.n_elastic, orig.n_elastic);
        assert_eq!(back.core_res.cpu.to_bits(), orig.core_res.cpu.to_bits());
        assert_eq!(back.elastic_res.ram_mb.to_bits(), orig.elastic_res.ram_mb.to_bits());
        assert_eq!(back.class, orig.class);
        assert_eq!(back.priority, orig.priority);
    }

    #[test]
    fn event_log_arrivals_are_exempt_from_caps() {
        // An app-trace line gets capped; the same tuple as a recorded
        // event-log arrival does not (it records what actually ran).
        let app = r#"{"arrival":0.0,"runtime":10.0,"n_core":100000,"core_cpu":1.0,"core_ram_mb":1.0}"#;
        let log = r#"{"ev":"arrival","t":0.0,"arrival":0.0,"runtime":10.0,"n_core":100000,"core_cpu":1.0,"core_ram_mb":1.0}"#;
        let opts = IngestOptions::default();
        assert!(TraceSource::from_jsonl_str(app, &opts).unwrap().requests()[0].n_core < 100_000);
        assert_eq!(TraceSource::from_jsonl_str(log, &opts).unwrap().requests()[0].n_core, 100_000);
    }

    #[test]
    fn csv_rejects_malformed_rows_with_line_numbers() {
        let s = "0,,1,0,,0,u,1,0,0.1,0.1,,\nnot,a,row\n";
        let err = TraceSource::from_csv_str(s, &IngestOptions::default()).unwrap_err();
        assert_eq!(err.line, 2);
        let s = "bad_time,,1,0,,0\n";
        let err = TraceSource::from_csv_str(s, &IngestOptions::default()).unwrap_err();
        assert_eq!(err.line, 1);
        assert!(err.msg.contains("timestamp"), "{}", err.msg);
    }

    #[test]
    fn csv_out_of_window_sentinel_rows_are_dropped() {
        // Job 1 "ends" at the 2^63−1 µs after-window sentinel: the row
        // carries no usable time, so the job counts as unfinished.
        // Job 2 is a normal finished job.
        let s = "0,,1,0,,0,u,1,0,0.1,0.1,,\n\
                 9223372036854775807,,1,0,,4,u,1,0,,,,\n\
                 0,,2,0,,0,u,1,0,0.1,0.1,,\n\
                 5000000,,2,0,,4,u,1,0,,,,\n";
        let trace = TraceSource::from_csv_str(s, &IngestOptions::default()).unwrap();
        assert_eq!(trace.len(), 1);
        assert_eq!(trace.skipped, 1);
        assert!((trace.requests()[0].runtime - 5.0).abs() < 1e-9);
    }

    #[test]
    fn empty_input_yields_empty_trace() {
        let src = TraceSource::from_jsonl_str("", &IngestOptions::default()).unwrap();
        assert!(src.is_empty());
        assert_eq!(src.span(), 0.0);
    }

    #[test]
    fn jsonl_deadline_round_trips_and_validates() {
        let r = line_req(
            r#"{"arrival":0.0,"runtime":10.0,"n_core":1,"core_cpu":1.0,"core_ram_mb":64,"deadline":25.0}"#,
        );
        assert_eq!(r.deadline, 25.0);
        // Absent deadline = none; the emitted fields omit it, so old
        // recordings stay byte-identical.
        let r2 = line_req(r#"{"arrival":0.0,"runtime":10.0,"n_core":1,"core_cpu":1.0,"core_ram_mb":64}"#);
        assert!(r2.deadline.is_infinite());
        assert!(!request_to_json_fields(&r2).iter().any(|(k, _)| *k == "deadline"));
        assert!(request_to_json_fields(&r).iter().any(|(k, _)| *k == "deadline"));
        let bad = r#"{"arrival":0.0,"runtime":10.0,"n_core":1,"core_cpu":1.0,"core_ram_mb":64,"deadline":-5.0}"#;
        let err = TraceSource::from_jsonl_str(bad, &IngestOptions::default()).unwrap_err();
        assert!(err.msg.contains("deadline"), "{}", err.msg);
    }

    // ---- machine_events --------------------------------------------------

    #[test]
    fn machine_events_basic_lifecycle() {
        // Two machines at t=0; m1 dies at 10s, comes back at 20s; m2
        // resized at 15s; a third machine joins at 30s.
        let s = "0,1,0,p,0.5,0.5\n\
                 0,2,0,p,1.0,1.0\n\
                 10000000,1,1,p,,\n\
                 15000000,2,2,p,0.25,0.25\n\
                 20000000,1,0,p,0.5,0.5\n\
                 30000000,3,0,p,1.0,1.0\n";
        let me = MachineEvents::from_csv_str(s, &IngestOptions::default()).unwrap();
        assert_eq!(me.n_machines(), 3);
        assert_eq!(me.present, vec![true, true, false]);
        assert_eq!(me.skipped, 0);
        assert_eq!(me.events.len(), 4);
        assert_eq!(me.events[0].time, 10.0);
        assert_eq!(me.events[0].kind, ClusterEventKind::Remove);
        // Capacities scaled by cpu_scale=32 / ram_scale_mb=131072.
        assert_eq!(me.capacities[0].cpu, 16.0);
        assert_eq!(me.capacities[0].ram_mb, 0.5 * 128.0 * 1024.0);
        let c = me.initial_cluster();
        assert_eq!(c.n_machines(), 3);
        assert!(c.is_down(2));
        assert!(!c.is_down(0));
    }

    #[test]
    fn machine_events_skips_sentinels_and_unknown_removes() {
        let s = "9223372036854775807,1,0,p,0.5,0.5\n\
                 0,7,1,p,,\n\
                 0,1,0,p,0.5,0.5\n";
        let me = MachineEvents::from_csv_str(s, &IngestOptions::default()).unwrap();
        assert_eq!(me.n_machines(), 1);
        assert_eq!(me.skipped, 2, "sentinel ADD + REMOVE of unknown machine");
    }

    #[test]
    fn machine_events_rejects_task_events_shape() {
        // A task_events row (13 columns) must fail fast, naming both
        // formats, not silently misparse.
        let s = "0,,1,0,,0,u,1,0,0.1,0.1,,\n";
        let err = MachineEvents::from_csv_str(s, &IngestOptions::default()).unwrap_err();
        assert_eq!(err.line, 1);
        assert!(err.msg.contains("task_events"), "{}", err.msg);
        assert!(err.msg.contains("machine_events"), "{}", err.msg);
        // Malformed numeric fields error with the line number.
        let bad = "0,xyz,0,p,0.5,0.5\n";
        let err = MachineEvents::from_csv_str(bad, &IngestOptions::default()).unwrap_err();
        assert!(err.msg.contains("machine id"), "{}", err.msg);
        let bad = "0,1,9,p,0.5,0.5\n";
        let err = MachineEvents::from_csv_str(bad, &IngestOptions::default()).unwrap_err();
        assert!(err.msg.contains("event type"), "{}", err.msg);
    }
}

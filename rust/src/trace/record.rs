//! Event-log recording: a [`TraceRecorder`] attaches to a
//! [`crate::sim::Simulation`] and emits one JSONL line per observable
//! scheduling event. The log is both an analysis artifact (allocation
//! timelines, rebalance causality) and a *replayable trace*: its
//! `arrival` lines carry the full request tuple in the native app-trace
//! format, so `record → ingest → replay` reproduces the original
//! [`crate::sim::SimResult`] bit-identically.
//!
//! Line schema (`"ev"` discriminates; all times in simulated seconds).
//! Identity fields, schema v2: `id` is the request's monotone
//! **submission seq** (the old dense id — stable, human-orderable),
//! while `slot`/`gen` carry the generational slab handle, so a log line
//! can be correlated with the recycled slot it ran in. Ingest ignores
//! all three (replay re-allocates), which is what keeps record → replay
//! bit-identical across the id representation change.
//!
//! | `ev` | fields | meaning |
//! |---|---|---|
//! | `meta` | `schema`, `source` | first line; format version |
//! | `arrival` | `t`, `id`, `slot`, `gen` + the app tuple (see [`crate::trace`]) | request submission |
//! | `alloc` | `t`, `id`, `slot`, `gen`, `grant`, `cause`, `src` | request `id`'s elastic grant became `grant` (admissions emit their initial grant) because `src` (a seq) arrived/departed |
//! | `rebalance` | `t`, `cause`, `src`, `changed` | summary: one scheduling action changed `changed` grants |
//! | `departure` | `t`, `id`, `slot`, `gen`, `turnaround`, `queuing`, `slowdown` | request completion with its §4.1 metrics |
//! | `end` | `t`, `events` | last line; run finished |

use std::io::Write;

use crate::core::ReqId;
use crate::sched::{ClusterView, Phase, ReqState};
use crate::util::json::Json;

use super::ingest::request_to_json_fields;

/// Version stamped into the `meta` line of every event log. v2 added
/// the generational identity fields (`slot`, `gen`) beside the
/// submission seq `id`; v1 logs (plain dense ids) still ingest — the
/// reader never keys on ids.
pub const TRACE_SCHEMA_VERSION: u64 = 2;

/// Records a simulation run as a JSONL event log (see the module docs
/// for the schema). Attach with [`crate::sim::Simulation::with_recorder`];
/// recording is purely observational and never perturbs the run — an
/// I/O failure mid-run (e.g. a full disk) prints one stderr warning,
/// disables further recording, and lets the simulation finish; the
/// truncated log is missing its `end` line, which marks it incomplete.
pub struct TraceRecorder {
    /// `None` after a write failure: recording is disabled, the run
    /// continues.
    out: Option<Box<dyn Write>>,
    /// Last grant emitted per **slot** (−1 = never emitted), so
    /// duplicate entries in the engine's changed-set produce one `alloc`
    /// line per actual change. Slot-keyed — O(active high-water), not
    /// O(total) — and reset at every arrival, because the arriving
    /// request may be reusing a recycled slot whose previous occupant's
    /// grant must not dedup the newcomer's first `alloc` line away.
    last_grant: Vec<i64>,
    lines: u64,
}

impl TraceRecorder {
    /// A recorder writing to `out`; emits the `meta` line immediately.
    pub fn new(out: Box<dyn Write>) -> Self {
        let mut rec = TraceRecorder {
            out: Some(out),
            last_grant: Vec::new(),
            lines: 0,
        };
        rec.write(Json::obj(vec![
            ("ev", Json::str("meta")),
            ("schema", Json::num(TRACE_SCHEMA_VERSION as f64)),
            ("source", Json::str("zoe-sim")),
        ]));
        rec
    }

    /// A recorder writing to a freshly created (buffered) file.
    pub fn to_path(path: &str) -> std::io::Result<Self> {
        let f = std::fs::File::create(path)?;
        Ok(Self::new(Box::new(std::io::BufWriter::new(f))))
    }

    /// Number of JSONL lines written so far (including `meta`).
    pub fn lines(&self) -> u64 {
        self.lines
    }

    /// Whether a write failure disabled recording mid-run.
    pub fn failed(&self) -> bool {
        self.out.is_none()
    }

    fn write(&mut self, j: Json) {
        let Some(out) = self.out.as_mut() else {
            return;
        };
        let mut s = j.to_string();
        s.push('\n');
        if let Err(e) = out.write_all(s.as_bytes()) {
            eprintln!("warning: trace recorder: write failed ({e}); recording disabled, the event log is incomplete");
            self.out = None;
            return;
        }
        self.lines += 1;
    }

    pub(crate) fn record_arrival(&mut self, t: f64, st: &ReqState) {
        // Fresh occupant of (possibly recycled) slot: reset the dedup
        // state so its first grant change always emits an alloc line.
        let idx = st.req.id.index();
        if self.last_grant.len() <= idx {
            self.last_grant.resize(idx + 1, -1);
        }
        self.last_grant[idx] = -1;
        let mut fields = vec![
            ("ev", Json::str("arrival")),
            ("t", Json::num(t)),
            ("id", Json::num(st.seq as f64)),
            ("slot", Json::num(st.req.id.slot as f64)),
            ("gen", Json::num(st.req.id.gen as f64)),
        ];
        fields.extend(request_to_json_fields(&st.req));
        self.write(Json::obj(fields));
    }

    /// Emit `alloc` lines for every request whose grant actually changed
    /// in the scheduling action that just ran — sourced from the core's
    /// [`crate::sched::Decision`] stream, read before the engine's
    /// apply-pass drains it — plus one `rebalance` summary when anything
    /// changed.
    pub(crate) fn record_changes(
        &mut self,
        t: f64,
        cause: &'static str,
        src_seq: u64,
        w: &ClusterView,
    ) {
        let mut n_changed = 0u64;
        for i in 0..w.decisions.len() {
            let id = w.decisions[i].id();
            // Present even if it departed within this same action — the
            // engine frees slots only after the recorder has run.
            let st = w.state(id);
            let idx = id.index();
            if st.phase != Phase::Running {
                // Departed (or preempted/re-queued) within the same
                // action. Forget the dedup state: the request holds
                // nothing now, so if it is ever re-admitted at its old
                // grant, that alloc line must be emitted, not deduped
                // away. (Built-in cores never take this branch — only
                // registered preempting cores do — so recorded logs of
                // the built-ins are byte-identical with or without it.)
                if idx < self.last_grant.len() {
                    self.last_grant[idx] = -1;
                }
                continue;
            }
            if self.last_grant.len() <= idx {
                self.last_grant.resize(idx + 1, -1);
            }
            let g = st.grant as i64;
            if self.last_grant[idx] == g {
                continue;
            }
            self.last_grant[idx] = g;
            n_changed += 1;
            self.write(Json::obj(vec![
                ("ev", Json::str("alloc")),
                ("t", Json::num(t)),
                ("id", Json::num(st.seq as f64)),
                ("slot", Json::num(id.slot as f64)),
                ("gen", Json::num(id.gen as f64)),
                ("grant", Json::num(st.grant as f64)),
                ("cause", Json::str(cause)),
                ("src", Json::num(src_seq as f64)),
            ]));
        }
        if n_changed > 0 {
            self.write(Json::obj(vec![
                ("ev", Json::str("rebalance")),
                ("t", Json::num(t)),
                ("cause", Json::str(cause)),
                ("src", Json::num(src_seq as f64)),
                ("changed", Json::num(n_changed as f64)),
            ]));
        }
    }

    pub(crate) fn record_departure(
        &mut self,
        t: f64,
        id: ReqId,
        seq: u64,
        turnaround: f64,
        queuing: f64,
        slowdown: f64,
    ) {
        self.write(Json::obj(vec![
            ("ev", Json::str("departure")),
            ("t", Json::num(t)),
            ("id", Json::num(seq as f64)),
            ("slot", Json::num(id.slot as f64)),
            ("gen", Json::num(id.gen as f64)),
            ("turnaround", Json::num(turnaround)),
            ("queuing", Json::num(queuing)),
            ("slowdown", Json::num(slowdown)),
        ]));
    }

    pub(crate) fn finish(&mut self, t: f64, events: u64) {
        self.write(Json::obj(vec![
            ("ev", Json::str("end")),
            ("t", Json::num(t)),
            ("events", Json::num(events as f64)),
        ]));
        if let Some(out) = self.out.as_mut() {
            if let Err(e) = out.flush() {
                eprintln!("warning: trace recorder: flush failed ({e}); the event log may be incomplete");
            }
        }
    }
}

/// A cloneable in-memory [`Write`] sink: every clone appends to the same
/// shared buffer. Lets tests and benches capture an event log without
/// touching disk (the recorder consumes its writer, so the caller keeps
/// a clone to read the contents back after the run).
#[derive(Clone, Debug, Default)]
pub struct SharedBuf(std::sync::Arc<std::sync::Mutex<Vec<u8>>>);

impl SharedBuf {
    /// An empty shared buffer.
    pub fn new() -> Self {
        Self::default()
    }

    /// The bytes written so far, decoded as UTF-8.
    pub fn contents(&self) -> String {
        String::from_utf8(self.0.lock().unwrap().clone()).expect("event logs are UTF-8")
    }
}

impl Write for SharedBuf {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_buf_clones_share_contents() {
        let a = SharedBuf::new();
        let mut b = a.clone();
        b.write_all(b"hello").unwrap();
        assert_eq!(a.contents(), "hello");
    }

    #[test]
    fn write_failure_disables_recording_without_panicking() {
        struct FailingWriter;
        impl Write for FailingWriter {
            fn write(&mut self, _: &[u8]) -> std::io::Result<usize> {
                Err(std::io::Error::new(std::io::ErrorKind::Other, "disk full"))
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut rec = TraceRecorder::new(Box::new(FailingWriter));
        assert!(rec.failed());
        assert_eq!(rec.lines(), 0);
        // Further writes are silent no-ops — the simulation keeps going.
        rec.finish(1.0, 2);
        assert_eq!(rec.lines(), 0);
    }

    #[test]
    fn recorder_emits_meta_line_first() {
        let buf = SharedBuf::new();
        let rec = TraceRecorder::new(Box::new(buf.clone()));
        assert_eq!(rec.lines(), 1);
        let first = buf.contents();
        let j = Json::parse(first.lines().next().unwrap()).unwrap();
        assert_eq!(j.get("ev").as_str(), Some("meta"));
        assert_eq!(j.get("schema").as_u64(), Some(TRACE_SCHEMA_VERSION));
    }
}

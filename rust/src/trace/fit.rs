//! Trace calibration: extract per-metric quantiles from an ingested
//! trace into piecewise-linear [`Empirical`] CDFs and assemble a
//! [`WorkloadSpec`] — the inverse of the synthetic generator. Fitted
//! control points sit *at* the probability grid, so the fitted
//! distribution's quantiles at grid points (including the 10/50/90th)
//! equal the trace's empirical quantiles exactly; between grid points
//! the interpolation (log-space for heavy-tailed metrics) carries the
//! usual piecewise-linear error.

use crate::core::AppClass;
use crate::util::dist::{Empirical, Mixture};
use crate::util::json::Json;
use crate::util::stats::Samples;
use crate::workload::{Caps, WorkloadSpec};

use super::TraceSource;

/// Probability grid the calibrator extracts quantiles at. Includes the
/// 10/50/90th percentiles the acceptance checks compare, plus enough
/// intermediate points to track the tail shape.
pub const FIT_GRID: [f64; 11] = [
    0.0, 0.05, 0.10, 0.25, 0.40, 0.50, 0.60, 0.75, 0.90, 0.95, 1.0,
];

/// Per-metric sample sets extracted from a trace — the raw material of
/// [`fit_workload`], also used by `zoe trace stats` and the fit-accuracy
/// property tests.
pub struct TraceStats {
    /// Isolated runtimes (s), one per application.
    pub runtime: Samples,
    /// Per-component CPU demands: each application contributes its core
    /// profile, plus its elastic profile when it has elastic components.
    pub cpu: Samples,
    /// Per-component RAM demands (MB), extracted like `cpu`.
    pub ram_mb: Samples,
    /// Inter-arrival gaps (s) between consecutive arrivals.
    pub interarrival: Samples,
    /// Core-component counts of B-E applications.
    pub batch_cores: Samples,
    /// Elastic-component counts of B-E applications.
    pub batch_elastic: Samples,
    /// (Core) component counts of B-R applications.
    pub rigid_components: Samples,
    /// Elastic-component counts of interactive applications.
    pub interactive_elastic: Samples,
    /// Number of interactive applications.
    pub n_interactive: usize,
    /// Number of batch-elastic applications.
    pub n_batch_elastic: usize,
    /// Number of batch-rigid applications.
    pub n_batch_rigid: usize,
    /// Peak concurrently in-system applications under the
    /// isolated-execution approximation (each app occupies
    /// `[arrival, arrival + runtime)`; queuing and contention can only
    /// stretch residence, never overlap more arrivals, so the true peak
    /// under any scheduler is at least the arrival overlap this counts
    /// at full allocation). This is the number to size the O(active)
    /// request slab — and the cluster — against.
    pub peak_concurrent: usize,
    /// Applications the ingest dropped *before* these stats were
    /// collected (CSV jobs with no submit or no end event — they never
    /// completed inside the trace window, so they have no runtime to
    /// fit). A fit is only as representative as its coverage; reports
    /// must surface this count instead of silently pretending the trace
    /// was fully fitted.
    pub skipped: usize,
}

impl TraceStats {
    /// Extract every sample set in one pass over the trace.
    pub fn collect(trace: &TraceSource) -> Self {
        let mut s = TraceStats {
            runtime: Samples::new(),
            cpu: Samples::new(),
            ram_mb: Samples::new(),
            interarrival: Samples::new(),
            batch_cores: Samples::new(),
            batch_elastic: Samples::new(),
            rigid_components: Samples::new(),
            interactive_elastic: Samples::new(),
            n_interactive: 0,
            n_batch_elastic: 0,
            n_batch_rigid: 0,
            peak_concurrent: 0,
            skipped: trace.skipped,
        };
        let mut prev: Option<f64> = None;
        let mut spans: Vec<(f64, f64)> = Vec::with_capacity(trace.len());
        for r in trace.requests() {
            spans.push((r.arrival, r.arrival + r.runtime));
            s.runtime.push(r.runtime);
            s.cpu.push(r.core_res.cpu);
            s.ram_mb.push(r.core_res.ram_mb);
            if r.n_elastic > 0 {
                s.cpu.push(r.elastic_res.cpu);
                s.ram_mb.push(r.elastic_res.ram_mb);
            }
            if let Some(p) = prev {
                s.interarrival.push(r.arrival - p);
            }
            prev = Some(r.arrival);
            match r.class {
                AppClass::Interactive => {
                    s.n_interactive += 1;
                    s.interactive_elastic.push(r.n_elastic.max(1) as f64);
                }
                AppClass::BatchElastic => {
                    s.n_batch_elastic += 1;
                    s.batch_cores.push(r.n_core as f64);
                    s.batch_elastic.push(r.n_elastic.max(1) as f64);
                }
                AppClass::BatchRigid => {
                    s.n_batch_rigid += 1;
                    s.rigid_components.push(r.n_core as f64);
                }
            }
        }
        s.peak_concurrent = peak_overlap(spans);
        s
    }

    /// Total number of applications seen.
    pub fn total(&self) -> usize {
        self.n_interactive + self.n_batch_elastic + self.n_batch_rigid
    }
}

/// Peak overlap of half-open `[start, end)` spans, by event sweep. An
/// arrival coinciding exactly with a departure counts both (the
/// simulator processes the arrival first, so both momentarily occupy
/// slab slots) — a conservative match for the slab's high-water mark.
fn peak_overlap(spans: Vec<(f64, f64)>) -> usize {
    let mut events: Vec<(f64, i32)> = Vec::with_capacity(spans.len() * 2);
    for (a, b) in spans {
        events.push((a, 1));
        events.push((b, -1));
    }
    // At equal times, arrivals (+1) before departures (−1).
    events.sort_by(|x, y| x.0.total_cmp(&y.0).then(y.1.cmp(&x.1)));
    let (mut cur, mut peak) = (0i64, 0i64);
    for (_, d) in events {
        cur += d as i64;
        peak = peak.max(cur);
    }
    peak.max(0) as usize
}

/// Fit a piecewise-linear CDF through the samples' quantiles at
/// [`FIT_GRID`]; `None` when there are no samples. Log-space
/// interpolation is used when requested and the support is strictly
/// positive (heavy-tailed metrics: runtimes, memory, counts).
fn fit_empirical(xs: &mut Samples, prefer_log: bool) -> Option<Empirical> {
    if xs.is_empty() {
        return None;
    }
    let mut pts: Vec<(f64, f64)> = FIT_GRID
        .iter()
        .map(|&p| (xs.percentile(p * 100.0), p))
        .collect();
    // Percentiles are monotone; clamp float wobble so the control
    // points satisfy Empirical's nondecreasing-value invariant.
    for i in 1..pts.len() {
        if pts[i].0 < pts[i - 1].0 {
            pts[i].0 = pts[i - 1].0;
        }
    }
    Some(if prefer_log && pts[0].0 > 0.0 {
        Empirical::new_log(pts)
    } else {
        Empirical::new(pts)
    })
}

/// Calibrate a [`WorkloadSpec`] from an ingested trace: quantile-fitted
/// CDFs for every distribution, class-mix fractions from the observed
/// counts, and the paper's schedulability caps. Distributions with no
/// samples in the trace (e.g. no interactive applications) fall back to
/// the paper spec's corresponding CDF.
///
/// # Panics
///
/// Panics on an empty trace — there is nothing to fit.
pub fn fit_workload(trace: &TraceSource) -> WorkloadSpec {
    let mut st = TraceStats::collect(trace);
    fit_workload_from_stats(&mut st)
}

/// [`fit_workload`] over already-collected [`TraceStats`] — callers
/// that also report on the stats (e.g. `zoe trace fit`'s comparison
/// table) avoid a second O(n) collection pass over the trace. Takes
/// `&mut` because quantile extraction sorts the sample sets (their
/// contents are unchanged).
///
/// # Panics
///
/// Panics when the stats cover zero applications.
pub fn fit_workload_from_stats(st: &mut TraceStats) -> WorkloadSpec {
    assert!(st.total() > 0, "cannot fit a workload from an empty trace");
    let paper = WorkloadSpec::paper();
    let caps = Caps::paper();
    let total = st.total() as f64;
    let n_batch = st.n_batch_elastic + st.n_batch_rigid;
    let interarrival = fit_empirical(&mut st.interarrival, true);
    WorkloadSpec {
        interactive_frac: st.n_interactive as f64 / total,
        batch_elastic_frac: if n_batch > 0 {
            st.n_batch_elastic as f64 / n_batch as f64
        } else {
            paper.batch_elastic_frac
        },
        cpu: fit_empirical(&mut st.cpu, false).expect("non-empty trace has cpu samples"),
        ram_mb: fit_empirical(&mut st.ram_mb, true).expect("non-empty trace has ram samples"),
        // A single fitted mode: the trace's gaps already contain
        // whatever bimodality the system had, so the mixture degenerates
        // to one empirical CDF (w0 = 1 ⇒ mode `a` always sampled).
        interarrival: match interarrival {
            Some(d) => Mixture { w0: 1.0, a: d.clone(), b: d },
            None => paper.interarrival.clone(),
        },
        runtime: fit_empirical(&mut st.runtime, true).expect("non-empty trace has runtimes"),
        batch_cores: fit_empirical(&mut st.batch_cores, false)
            .unwrap_or_else(|| paper.batch_cores.clone()),
        batch_elastic: fit_empirical(&mut st.batch_elastic, true)
            .unwrap_or_else(|| paper.batch_elastic.clone()),
        rigid_components: fit_empirical(&mut st.rigid_components, true)
            .unwrap_or_else(|| paper.rigid_components.clone()),
        interactive_elastic: fit_empirical(&mut st.interactive_elastic, true)
            .unwrap_or_else(|| paper.interactive_elastic.clone()),
        interactive_runtime_scale: 1.0,
        interactive_priority: paper.interactive_priority,
        max_core_cpu: caps.max_core_cpu,
        max_core_ram_mb: caps.max_core_ram_mb,
        max_full_cpu: caps.max_full_cpu,
        max_full_ram_mb: caps.max_full_ram_mb,
        arrival_scale: 1.0,
        deadline_frac: 0.0,
        inelastic_mode: false,
    }
}

fn empirical_to_json(d: &Empirical) -> Json {
    Json::obj(vec![
        ("log", Json::Bool(d.log_space())),
        (
            "points",
            Json::Arr(
                d.points()
                    .iter()
                    .map(|&(v, p)| Json::Arr(vec![Json::num(v), Json::num(p)]))
                    .collect(),
            ),
        ),
    ])
}

/// Serialize a [`WorkloadSpec`] (e.g. a fitted one) as JSON for
/// inspection and external tooling: every distribution as its
/// `{log, points}` control-point list, plus the scalar knobs.
pub fn spec_to_json(spec: &WorkloadSpec) -> Json {
    Json::obj(vec![
        ("interactive_frac", Json::num(spec.interactive_frac)),
        ("batch_elastic_frac", Json::num(spec.batch_elastic_frac)),
        ("cpu", empirical_to_json(&spec.cpu)),
        ("ram_mb", empirical_to_json(&spec.ram_mb)),
        (
            "interarrival",
            Json::obj(vec![
                ("w0", Json::num(spec.interarrival.w0)),
                ("a", empirical_to_json(&spec.interarrival.a)),
                ("b", empirical_to_json(&spec.interarrival.b)),
            ]),
        ),
        ("runtime", empirical_to_json(&spec.runtime)),
        ("batch_cores", empirical_to_json(&spec.batch_cores)),
        ("batch_elastic", empirical_to_json(&spec.batch_elastic)),
        ("rigid_components", empirical_to_json(&spec.rigid_components)),
        ("interactive_elastic", empirical_to_json(&spec.interactive_elastic)),
        ("interactive_runtime_scale", Json::num(spec.interactive_runtime_scale)),
        ("interactive_priority", Json::num(spec.interactive_priority)),
        ("max_core_cpu", Json::num(spec.max_core_cpu)),
        ("max_core_ram_mb", Json::num(spec.max_core_ram_mb)),
        ("max_full_cpu", Json::num(spec.max_full_cpu)),
        ("max_full_ram_mb", Json::num(spec.max_full_ram_mb)),
        ("arrival_scale", Json::num(spec.arrival_scale)),
        ("deadline_frac", Json::num(spec.deadline_frac)),
        ("inelastic_mode", Json::Bool(spec.inelastic_mode)),
    ])
}

fn empirical_from_json(v: &Json) -> Option<Empirical> {
    let pts = v
        .get("points")
        .as_arr()?
        .iter()
        .map(|p| {
            let p = p.as_arr()?;
            if p.len() != 2 {
                return None;
            }
            Some((p[0].as_f64()?, p[1].as_f64()?))
        })
        .collect::<Option<Vec<(f64, f64)>>>()?;
    Some(if v.get("log").as_bool()? {
        Empirical::new_log(pts)
    } else {
        Empirical::new(pts)
    })
}

/// Inverse of [`spec_to_json`]: rebuild a [`WorkloadSpec`] from its JSON
/// form — what lets a distributed-sweep coordinator ship a (possibly
/// fitted) spec to workers on other hosts. `None` on shape mismatch; a
/// missing `deadline_frac` (files written before the SLO knob existed)
/// defaults to `0.0`.
///
/// The control points travel as shortest-roundtrip decimal text, which
/// `f64` parsing recovers exactly, so a round-tripped spec samples
/// bit-identical workloads.
///
/// # Panics
///
/// Panics when the control points violate [`Empirical`]'s invariants
/// (non-monotone CDF, non-positive log-space support) — same as
/// constructing the distribution directly.
pub fn spec_from_json(v: &Json) -> Option<WorkloadSpec> {
    let interarrival = v.get("interarrival");
    Some(WorkloadSpec {
        interactive_frac: v.get("interactive_frac").as_f64()?,
        batch_elastic_frac: v.get("batch_elastic_frac").as_f64()?,
        cpu: empirical_from_json(v.get("cpu"))?,
        ram_mb: empirical_from_json(v.get("ram_mb"))?,
        interarrival: Mixture {
            w0: interarrival.get("w0").as_f64()?,
            a: empirical_from_json(interarrival.get("a"))?,
            b: empirical_from_json(interarrival.get("b"))?,
        },
        runtime: empirical_from_json(v.get("runtime"))?,
        batch_cores: empirical_from_json(v.get("batch_cores"))?,
        batch_elastic: empirical_from_json(v.get("batch_elastic"))?,
        rigid_components: empirical_from_json(v.get("rigid_components"))?,
        interactive_elastic: empirical_from_json(v.get("interactive_elastic"))?,
        interactive_runtime_scale: v.get("interactive_runtime_scale").as_f64()?,
        interactive_priority: v.get("interactive_priority").as_f64()?,
        max_core_cpu: v.get("max_core_cpu").as_f64()?,
        max_core_ram_mb: v.get("max_core_ram_mb").as_f64()?,
        max_full_cpu: v.get("max_full_cpu").as_f64()?,
        max_full_ram_mb: v.get("max_full_ram_mb").as_f64()?,
        arrival_scale: v.get("arrival_scale").as_f64()?,
        deadline_frac: if v.get("deadline_frac").is_null() {
            0.0
        } else {
            v.get("deadline_frac").as_f64()?
        },
        inelastic_mode: v.get("inelastic_mode").as_bool()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::unit_request;

    #[test]
    fn fit_hits_grid_quantiles_exactly() {
        let mut xs = Samples::new();
        for i in 0..1000 {
            xs.push(1.0 + i as f64); // uniform 1..=1000
        }
        let d = fit_empirical(&mut xs.clone(), true).unwrap();
        for p in [0.10, 0.50, 0.90] {
            let want = xs.percentile(p * 100.0);
            let got = d.quantile(p);
            assert!(
                (got - want).abs() <= 1e-9 * want.abs(),
                "p{}: {got} vs {want}",
                p * 100.0
            );
        }
    }

    #[test]
    fn fit_handles_constant_samples() {
        let mut xs = Samples::new();
        for _ in 0..10 {
            xs.push(42.0);
        }
        let d = fit_empirical(&mut xs, true).unwrap();
        // Log-space interpolation round-trips through ln/exp, which is
        // exact only to an ulp — compare with a tolerance.
        for p in [0.0, 0.5, 1.0] {
            let q = d.quantile(p);
            assert!((q - 42.0).abs() < 1e-9, "quantile({p}) = {q}");
        }
    }

    #[test]
    fn fit_falls_back_to_linear_on_zero_support() {
        let mut xs = Samples::new();
        xs.push(0.0);
        xs.push(10.0);
        let d = fit_empirical(&mut xs, true).unwrap();
        assert!(!d.log_space());
        assert_eq!(d.quantile(0.0), 0.0);
    }

    #[test]
    fn stats_collects_classes_and_interarrivals() {
        let reqs = vec![
            unit_request(0, 0.0, 10.0, 2, 0),  // B-R (builder reclassifies)
            unit_request(1, 5.0, 20.0, 1, 4),  // B-E
            unit_request(2, 9.0, 30.0, 1, 2),  // B-E
        ];
        let trace = TraceSource::new(reqs);
        let st = TraceStats::collect(&trace);
        assert_eq!(st.total(), 3);
        assert_eq!(st.n_batch_rigid, 1);
        assert_eq!(st.n_batch_elastic, 2);
        assert_eq!(st.interarrival.len(), 2);
        assert_eq!(st.runtime.len(), 3);
        // rigid app contributes 1 cpu sample, elastic apps 2 each
        assert_eq!(st.cpu.len(), 5);
        // Spans [0,10), [5,25), [9,39): all three overlap during [9,10).
        assert_eq!(st.peak_concurrent, 3);
        assert_eq!(st.skipped, 0);
    }

    #[test]
    fn stats_surface_ingest_skip_count() {
        // CSV jobs dropped during aggregation (never completed in the
        // window) must show up on the stats instead of vanishing.
        let mut trace = TraceSource::new(vec![unit_request(0, 0.0, 10.0, 1, 0)]);
        trace.skipped = 7;
        let st = TraceStats::collect(&trace);
        assert_eq!(st.skipped, 7);
    }

    #[test]
    fn peak_concurrency_counts_touching_spans_conservatively() {
        // Back-to-back spans: the second arrives exactly as the first
        // ends — the sweep counts both (arrival before departure at
        // ties, matching the simulator's event order).
        let reqs = vec![
            unit_request(0, 0.0, 10.0, 1, 0),
            unit_request(1, 10.0, 10.0, 1, 0),
        ];
        let st = TraceStats::collect(&TraceSource::new(reqs));
        assert_eq!(st.peak_concurrent, 2);
        // Fully disjoint spans never overlap.
        let reqs = vec![
            unit_request(0, 0.0, 5.0, 1, 0),
            unit_request(1, 100.0, 5.0, 1, 0),
        ];
        let st = TraceStats::collect(&TraceSource::new(reqs));
        assert_eq!(st.peak_concurrent, 1);
    }

    #[test]
    fn spec_json_roundtrip_samples_identically() {
        // A spec that went through JSON text must generate a bit-identical
        // workload — the property the distributed sweep ships specs under.
        for spec in [WorkloadSpec::paper(), {
            let mut s = WorkloadSpec::paper_batch_only();
            s.deadline_frac = 3.0;
            s.arrival_scale = 1.5;
            s
        }] {
            let txt = spec_to_json(&spec).to_string();
            let back = spec_from_json(&Json::parse(&txt).unwrap()).unwrap();
            let a = spec.generate(200, 7);
            let b = back.generate(200, 7);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
                assert_eq!(x.runtime.to_bits(), y.runtime.to_bits());
                assert_eq!(x.n_core, y.n_core);
                assert_eq!(x.n_elastic, y.n_elastic);
                assert_eq!(x.core_res.cpu.to_bits(), y.core_res.cpu.to_bits());
                assert_eq!(x.deadline.to_bits(), y.deadline.to_bits());
            }
        }
        // Pre-SLO files lack deadline_frac: defaults to 0.0.
        let mut j = spec_to_json(&WorkloadSpec::paper());
        if let Json::Obj(o) = &mut j {
            o.remove("deadline_frac");
        }
        assert_eq!(spec_from_json(&j).unwrap().deadline_frac, 0.0);
    }

    #[test]
    fn fitted_spec_serializes_to_json() {
        let reqs = (0..50)
            .map(|i| unit_request(i, i as f64 * 3.0, 10.0 + i as f64, 1, (i % 5) as u32))
            .collect();
        let spec = fit_workload(&TraceSource::new(reqs));
        let j = spec_to_json(&spec);
        let rt = Json::parse(&j.to_string()).unwrap();
        assert_eq!(rt.get("inelastic_mode").as_bool(), Some(false));
        assert!(rt.get("runtime").get("points").as_arr().unwrap().len() == FIT_GRID.len());
    }
}

//! The request abstraction of §2.2: an analytic application reduced to the
//! tuple the scheduler needs — arrival time, priority, core and elastic
//! component demands, and isolated execution time.

/// Two-dimensional resource vector (the paper simulates CPU + RAM; §4.1).
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct Resources {
    /// CPU cores (fractional allowed, the traces contain <1-core tasks).
    pub cpu: f64,
    /// RAM in megabytes.
    pub ram_mb: f64,
}

impl Resources {
    /// The zero vector (no CPU, no RAM).
    pub const ZERO: Resources = Resources { cpu: 0.0, ram_mb: 0.0 };

    /// A resource vector from its two components.
    pub fn new(cpu: f64, ram_mb: f64) -> Self {
        Resources { cpu, ram_mb }
    }

    /// Does this demand fit within `avail` (with a small tolerance)?
    #[inline]
    pub fn fits_in(&self, avail: &Resources) -> bool {
        self.cpu <= avail.cpu + 1e-9 && self.ram_mb <= avail.ram_mb + 1e-9
    }

    /// Componentwise add.
    #[inline]
    pub fn add(&mut self, o: &Resources) {
        self.cpu += o.cpu;
        self.ram_mb += o.ram_mb;
    }

    /// Componentwise subtract.
    #[inline]
    pub fn sub(&mut self, o: &Resources) {
        self.cpu -= o.cpu;
        self.ram_mb -= o.ram_mb;
    }

    /// This vector scaled by `k` (e.g. per-component demand × count).
    #[inline]
    pub fn scaled(&self, k: f64) -> Resources {
        Resources {
            cpu: self.cpu * k,
            ram_mb: self.ram_mb * k,
        }
    }

    /// Serialize bit-exactly for wire transport (distributed sweeps).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{f64_to_json, Json};
        Json::obj(vec![
            ("cpu", f64_to_json(self.cpu)),
            ("ram_mb", f64_to_json(self.ram_mb)),
        ])
    }

    /// Inverse of [`Resources::to_json`]; `None` on shape mismatch.
    pub fn from_json(v: &crate::util::json::Json) -> Option<Resources> {
        use crate::util::json::f64_from_json;
        Some(Resources {
            cpu: f64_from_json(v.get("cpu"))?,
            ram_mb: f64_from_json(v.get("ram_mb"))?,
        })
    }
}

/// Component classes — the paper's central modeling idea (§2.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ComponentClass {
    /// Compulsory for the application to produce useful work. Never
    /// preempted.
    Core,
    /// Optionally contributes (shorter runtime); preemptible.
    Elastic,
}

/// What kind of application a request belongs to (workload taxonomy, §4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AppClass {
    /// Batch with elastic components (e.g. Spark). "B-E" in the figures.
    BatchElastic,
    /// Batch with only core components (e.g. TensorFlow). "B-R".
    BatchRigid,
    /// Interactive (human in the loop, e.g. a Notebook). "Int".
    Interactive,
}

impl AppClass {
    /// The figure-legend abbreviation ("B-E" / "B-R" / "Int").
    pub fn label(&self) -> &'static str {
        match self {
            AppClass::BatchElastic => "B-E",
            AppClass::BatchRigid => "B-R",
            AppClass::Interactive => "Int",
        }
    }

    /// Inverse of [`AppClass::label`]; `None` for unknown labels.
    pub fn from_label(s: &str) -> Option<AppClass> {
        match s {
            "B-E" => Some(AppClass::BatchElastic),
            "B-R" => Some(AppClass::BatchRigid),
            "Int" => Some(AppClass::Interactive),
            _ => None,
        }
    }
}

/// Generational request handle: `slot` indexes the executor's request
/// table and `gen` distinguishes successive occupants of the same slot.
///
/// Slots are **recycled**: when a request completes, its slot returns to
/// a free list (lowest-free-slot-first) and the slot's generation is
/// bumped, so every layer that stores or transports ids — the event
/// heap, departure predictions, decision streams, trace logs, the Zoe
/// master's container maps — can detect a stale handle in O(1) instead
/// of growing with *total* submissions. Two ids are equal only when both
/// slot and generation match; a handle whose generation no longer
/// matches the table's is *stale* and must be dropped, exactly like a
/// stale lazy-deleted heap entry.
///
/// `ReqId` deliberately implements no ordering: slot order is **not**
/// submission order once slots recycle. Deterministic tie-breaks use the
/// monotone per-request sequence number
/// ([`crate::sched::ReqState::seq`]) instead.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ReqId {
    /// Index into the executor's request table (recycled).
    pub slot: u32,
    /// Generation of the slot this handle was allocated at.
    pub gen: u32,
}

impl ReqId {
    /// A handle from its two components.
    pub fn new(slot: u32, gen: u32) -> Self {
        ReqId { slot, gen }
    }

    /// The slot as a table index.
    #[inline]
    pub fn index(&self) -> usize {
        self.slot as usize
    }
}

/// A bare `u32` converts to a generation-0 handle — the dense-id form
/// every pre-slab call site (and test) used, valid as long as the slot
/// was never recycled.
impl From<u32> for ReqId {
    fn from(slot: u32) -> Self {
        ReqId { slot, gen: 0 }
    }
}

impl std::fmt::Display for ReqId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}.{}", self.slot, self.gen)
    }
}

/// A request: the scheduling view of an analytic application.
///
/// Components within a class are homogeneous (the paper's unit model,
/// generalized to 2-D per-component demands); `n_core` components each
/// require `core_res`, `n_elastic` each require `elastic_res`.
#[derive(Clone, Debug)]
pub struct Request {
    /// Generational handle into the executor's request table. Assigned
    /// (overwritten) by the table at allocation time — builders and
    /// trace parsers only carry a placeholder.
    pub id: ReqId,
    /// Workload-taxonomy class (§4.1).
    pub class: AppClass,
    /// Arrival (submission) time, seconds.
    pub arrival: f64,
    /// Isolated execution time T_i: runtime with ALL components allocated.
    pub runtime: f64,
    /// Number of core components (≥1 for any useful application).
    pub n_core: u32,
    /// Per-core-component resources.
    pub core_res: Resources,
    /// Number of elastic components (0 for rigid applications).
    pub n_elastic: u32,
    /// Per-elastic-component resources.
    pub elastic_res: Resources,
    /// Externally-assigned priority (higher = more urgent). Interactive
    /// applications get a high priority in the preemption experiments.
    pub priority: f64,
    /// Optional completion deadline, seconds **relative to arrival**
    /// (`f64::INFINITY` = no deadline). Purely observational: the
    /// schedulers ignore it, the metrics layer reports met/missed.
    pub deadline: f64,
}

impl Request {
    /// Total work in component-seconds: W_i = T_i × (C_i + E_i)  (§2.2).
    pub fn work(&self) -> f64 {
        self.runtime * (self.n_core + self.n_elastic) as f64
    }

    /// Progress rate when granted `g` elastic components.
    pub fn rate(&self, g: u32) -> f64 {
        debug_assert!(g <= self.n_elastic);
        (self.n_core + g) as f64
    }

    /// Aggregate resources of all core components.
    pub fn core_total(&self) -> Resources {
        self.core_res.scaled(self.n_core as f64)
    }

    /// Aggregate resources when fully allocated.
    pub fn full_total(&self) -> Resources {
        let mut r = self.core_total();
        r.add(&self.elastic_res.scaled(self.n_elastic as f64));
        r
    }

    /// Is this a rigid request (no elastic components)?
    pub fn is_rigid(&self) -> bool {
        self.n_elastic == 0
    }

    /// Serialize bit-exactly for wire transport: a distributed-sweep
    /// coordinator ships an ingested trace inline with this. Unlike the
    /// ingest JSONL schema, every float (including an infinite
    /// `deadline`) survives exactly.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{f64_to_json, Json};
        Json::obj(vec![
            ("id", Json::num(self.id.slot as f64)),
            ("class", Json::str(self.class.label())),
            ("arrival", f64_to_json(self.arrival)),
            ("runtime", f64_to_json(self.runtime)),
            ("n_core", Json::num(self.n_core as f64)),
            ("core_res", self.core_res.to_json()),
            ("n_elastic", Json::num(self.n_elastic as f64)),
            ("elastic_res", self.elastic_res.to_json()),
            ("priority", f64_to_json(self.priority)),
            ("deadline", f64_to_json(self.deadline)),
        ])
    }

    /// Inverse of [`Request::to_json`]; `None` on shape mismatch. The
    /// id comes back generation-0 — a placeholder, like every id ahead
    /// of the executor's slab allocation.
    pub fn from_json(v: &crate::util::json::Json) -> Option<Request> {
        use crate::util::json::f64_from_json;
        Some(Request {
            id: ReqId::from(v.get("id").as_u64()? as u32),
            class: AppClass::from_label(v.get("class").as_str()?)?,
            arrival: f64_from_json(v.get("arrival"))?,
            runtime: f64_from_json(v.get("runtime"))?,
            n_core: v.get("n_core").as_u64()? as u32,
            core_res: Resources::from_json(v.get("core_res"))?,
            n_elastic: v.get("n_elastic").as_u64()? as u32,
            elastic_res: Resources::from_json(v.get("elastic_res"))?,
            priority: f64_from_json(v.get("priority"))?,
            deadline: f64_from_json(v.get("deadline"))?,
        })
    }
}

/// Builder with reasonable defaults for tests and examples.
#[derive(Clone, Debug)]
pub struct RequestBuilder {
    req: Request,
}

impl RequestBuilder {
    /// A builder for request `id` (anything convertible to a [`ReqId`],
    /// e.g. a bare `u32`): 1 core of (1 CPU, 1 GB), runtime 1 s.
    pub fn new(id: impl Into<ReqId>) -> Self {
        RequestBuilder {
            req: Request {
                id: id.into(),
                class: AppClass::BatchElastic,
                arrival: 0.0,
                runtime: 1.0,
                n_core: 1,
                core_res: Resources::new(1.0, 1024.0),
                n_elastic: 0,
                elastic_res: Resources::new(1.0, 1024.0),
                priority: 0.0,
                deadline: f64::INFINITY,
            },
        }
    }

    /// Set the arrival (submission) time, seconds.
    pub fn arrival(mut self, t: f64) -> Self {
        self.req.arrival = t;
        self
    }

    /// Set the isolated execution time T_i, seconds.
    pub fn runtime(mut self, t: f64) -> Self {
        self.req.runtime = t;
        self
    }

    /// Set the core components: `n` of them, each demanding `res`.
    pub fn cores(mut self, n: u32, res: Resources) -> Self {
        self.req.n_core = n;
        self.req.core_res = res;
        self
    }

    /// Set the elastic components; `n == 0` reclassifies as B-R.
    pub fn elastics(mut self, n: u32, res: Resources) -> Self {
        self.req.n_elastic = n;
        self.req.elastic_res = res;
        if n == 0 {
            self.req.class = AppClass::BatchRigid;
        }
        self
    }

    /// Set the application class explicitly.
    pub fn class(mut self, c: AppClass) -> Self {
        self.req.class = c;
        self
    }

    /// Set the external priority (higher = more urgent).
    pub fn priority(mut self, p: f64) -> Self {
        self.req.priority = p;
        self
    }

    /// Set the completion deadline, seconds relative to arrival
    /// (`f64::INFINITY` = none, the default).
    pub fn deadline(mut self, d: f64) -> Self {
        self.req.deadline = d;
        self
    }

    /// Validate and return the request.
    pub fn build(self) -> Request {
        let r = &self.req;
        assert!(r.n_core >= 1, "a request needs at least one core component");
        assert!(r.runtime > 0.0, "runtime must be positive");
        self.req
    }
}

/// Convenience for the paper's 1-D "units" examples: a request whose
/// components each take 1 CPU unit and no RAM distinction.
pub fn unit_request(id: impl Into<ReqId>, arrival: f64, runtime: f64, c: u32, e: u32) -> Request {
    let unit = Resources::new(1.0, 1.0);
    RequestBuilder::new(id)
        .arrival(arrival)
        .runtime(runtime)
        .cores(c, unit)
        .elastics(e, unit)
        .build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn work_model() {
        let r = unit_request(0, 0.0, 10.0, 3, 4); // Fig 1 request A
        assert_eq!(r.work(), 70.0);
        assert_eq!(r.rate(0), 3.0);
        assert_eq!(r.rate(4), 7.0);
        assert!(!r.is_rigid());
    }

    #[test]
    fn totals() {
        let r = RequestBuilder::new(1)
            .cores(2, Resources::new(2.0, 4096.0))
            .elastics(3, Resources::new(1.0, 2048.0))
            .runtime(5.0)
            .build();
        let ct = r.core_total();
        assert_eq!(ct.cpu, 4.0);
        assert_eq!(ct.ram_mb, 8192.0);
        let ft = r.full_total();
        assert_eq!(ft.cpu, 7.0);
        assert_eq!(ft.ram_mb, 8192.0 + 6144.0);
    }

    #[test]
    #[should_panic]
    fn zero_core_rejected() {
        RequestBuilder::new(2).cores(0, Resources::ZERO).build();
    }

    #[test]
    fn generational_ids_distinguish_slot_occupants() {
        let a = ReqId::new(3, 0);
        let b = ReqId::new(3, 1);
        assert_ne!(a, b, "same slot, different generation");
        assert_eq!(ReqId::from(3u32), a, "bare u32 = generation 0");
        assert_eq!(a.index(), 3);
        assert_eq!(b.to_string(), "3.1");
    }

    #[test]
    fn fits_in_with_tolerance() {
        let a = Resources::new(1.0, 100.0);
        assert!(a.fits_in(&Resources::new(1.0, 100.0)));
        assert!(!a.fits_in(&Resources::new(0.5, 100.0)));
    }
}

//! Core domain model: resources, component classes, requests
//! (= analytic applications as the scheduler sees them, §2.2).

mod request;

pub use request::*;

//! # zoe-flex — Flexible Scheduling of Distributed Analytic Applications
//!
//! Reproduction of Pace, Venzano, Carra, Michiardi, *"Flexible Scheduling of
//! Distributed Analytic Applications"* (2016) — the **Zoe** scheduler — as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's contribution: the flexible scheduling
//!   heuristic (Algorithm 1) with core/elastic component classes, the rigid
//!   and malleable comparators, pluggable sorting policies (FIFO / SJF /
//!   SRPT / HRRN and the Table-1 size definitions), a trace-driven
//!   discrete-event simulator, and the full Zoe system (master, state store,
//!   application CL, Swarm-like container back-end).
//! * **L2/L1 (python, build-time only)** — the analytic *work* the scheduled
//!   applications execute (ALS / ridge-regression steps built on Pallas
//!   kernels), AOT-lowered to HLO text and executed from rust through PJRT
//!   (`runtime` module). Python is never on the request path.
//!
//! Start with [`sched::FlexibleScheduler`] and [`sim::Simulation`] for
//! single runs, [`sim::ExperimentPlan`] for parallel multi-seed sweeps,
//! [`trace`] for ingesting/recording/replaying real cluster traces,
//! or the full system in [`zoe`]. ARCHITECTURE.md maps the paper's
//! concepts onto these modules.

#![warn(missing_docs)]

pub mod backend;
pub mod cache;
pub mod core;
pub mod policy;
pub mod pool;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod slo;
pub mod sweep;
pub mod trace;
pub mod util;
pub mod workload;
pub mod zoe;

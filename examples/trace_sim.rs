//! Trace-driven simulation driver (§4): generate a Google-trace-shaped
//! workload, run it through a chosen scheduler × policy, and print the
//! paper's evaluation metrics.
//!
//! ```sh
//! cargo run --release --example trace_sim -- \
//!     --apps 8000 --seed 1 --sched flexible --policy sjf
//! ```

use zoe::policy::{Discipline, Policy, SizeDim};
use zoe::pool::Cluster;
use zoe::sched::SchedSpec;
use zoe::sim::simulate;
use zoe::util::bench::print_boxplot_row;
use zoe::util::cli::Args;
use zoe::workload::WorkloadSpec;

fn parse_policy(s: &str) -> Policy {
    match s {
        "fifo" => Policy::FIFO,
        "sjf" => Policy::sjf(),
        "srpt" => Policy::srpt(),
        "hrrn" => Policy::hrrn(),
        "sjf2d" => Policy::new(Discipline::Sjf, SizeDim::D2),
        "sjf3d" => Policy::new(Discipline::Sjf, SizeDim::D3),
        other => panic!("unknown policy '{other}' (fifo|sjf|srpt|hrrn|sjf2d|sjf3d)"),
    }
}

fn parse_sched(s: &str) -> SchedSpec {
    // The shared registry parser: built-in generations plus any
    // registered external core; its error lists the valid names.
    s.parse().unwrap_or_else(|e| panic!("{e}"))
}

fn main() {
    let args = Args::from_env();
    let apps = args.u64_or("apps", 8000) as u32;
    let seed = args.u64_or("seed", 1);
    let sched = parse_sched(&args.get_or("sched", "flexible"));
    let policy = parse_policy(&args.get_or("policy", "fifo"));
    let interactive = args.has("interactive");

    let mut spec = if interactive {
        WorkloadSpec::paper()
    } else {
        WorkloadSpec::paper_batch_only()
    };
    spec.arrival_scale = args.f64_or("arrival-scale", 1.0);
    let requests = spec.generate(apps, seed);
    println!(
        "workload: {} apps, last arrival at {:.1} days (seed {seed})",
        requests.len(),
        requests.last().unwrap().arrival / 86400.0
    );
    println!("scheduler: {} | policy: {}", sched.label(), policy.label());

    let t0 = std::time::Instant::now();
    let mut res = simulate(requests, Cluster::paper_sim(), policy, sched);
    println!(
        "simulated {:.1} days in {:.2}s wall ({:.0} events/s)",
        res.end_time / 86400.0,
        t0.elapsed().as_secs_f64(),
        res.events as f64 / t0.elapsed().as_secs_f64()
    );
    println!("{}", res.summary());

    println!("\nturnaround (s):");
    print_boxplot_row("  all", &res.turnaround.boxplot());
    for c in [
        zoe::core::AppClass::BatchElastic,
        zoe::core::AppClass::BatchRigid,
        zoe::core::AppClass::Interactive,
    ] {
        let label = format!("  {}", c.label());
        let b = res.class_mut(c).turnaround.boxplot();
        if b.n > 0 {
            print_boxplot_row(&label, &b);
        }
    }
    println!("\nqueuing time (s):");
    print_boxplot_row("  all", &res.queuing.boxplot());
    println!("\nslowdown (effective/nominal):");
    print_boxplot_row("  all", &res.slowdown.boxplot());
    println!("\nqueue sizes (time-weighted):");
    print_boxplot_row("  pending", &res.pending_q.boxplot());
    print_boxplot_row("  running", &res.running_q.boxplot());
    println!("\nallocation (fraction of cluster):");
    print_boxplot_row("  cpu", &res.cpu_alloc.boxplot());
    print_boxplot_row("  ram", &res.ram_alloc.boxplot());
}

//! Quickstart: the smallest useful program against the public API.
//!
//! Builds a handful of analytic applications (a Spark-like elastic job, a
//! TensorFlow-like rigid job, a Notebook), schedules them on a small
//! cluster with the flexible heuristic, and prints what happened.

use zoe::core::{AppClass, RequestBuilder, Resources};
use zoe::policy::Policy;
use zoe::pool::Cluster;
use zoe::sched::SchedKind;
use zoe::sim::simulate;

fn main() {
    // A 4-machine cluster, 16 cores / 64 GB each.
    let cluster = Cluster::uniform(4, Resources::new(16.0, 64.0 * 1024.0));

    // A Spark-like application: 3 core components (client, master, one
    // worker) plus 12 elastic workers. 2 cores / 8 GB per component.
    let spark = RequestBuilder::new(0)
        .class(AppClass::BatchElastic)
        .arrival(0.0)
        .runtime(120.0)
        .cores(3, Resources::new(2.0, 8192.0))
        .elastics(12, Resources::new(2.0, 8192.0))
        .build();

    // A distributed-TensorFlow-like application: rigid, 5 parameter
    // servers + 10 workers, all core.
    let tf = RequestBuilder::new(1)
        .class(AppClass::BatchRigid)
        .arrival(10.0)
        .runtime(300.0)
        .cores(15, Resources::new(1.0, 16384.0))
        .elastics(0, Resources::ZERO)
        .build();

    // An interactive notebook: 1 core component + a few elastic executors.
    let notebook = RequestBuilder::new(2)
        .class(AppClass::Interactive)
        .arrival(20.0)
        .runtime(600.0)
        .cores(1, Resources::new(1.0, 4096.0))
        .elastics(4, Resources::new(1.0, 4096.0))
        .priority(1.0)
        .build();

    let mut res = simulate(
        vec![spark, tf, notebook],
        cluster,
        Policy::FIFO,
        SchedKind::Flexible,
    );

    println!("completed {} applications:", res.completed);
    println!("  mean turnaround : {:>8.1} s", res.turnaround.mean());
    println!("  mean queuing    : {:>8.1} s", res.queuing.mean());
    println!("  mean slowdown   : {:>8.2}×", res.slowdown.mean());
    println!(
        "  peak cpu alloc  : {:>8.1} %",
        100.0 * res.cpu_alloc.percentile(100.0)
    );
    println!("\nNext: examples/illustrative.rs (Fig. 1), examples/trace_sim.rs (§4),");
    println!("      examples/zoe_e2e.rs (the full Zoe system on real PJRT compute).");
}

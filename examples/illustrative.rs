//! The paper's illustrative example (Fig. 1): four requests, a 10-unit
//! cluster, and three schedulers. Reproduces the paper's turnaround
//! averages — rigid 25 s, malleable 20 s, flexible 19.25 s — and prints a
//! timeline of the flexible run.
//!
//! Parameters (derived from the figure): C_i = 3, T_i = 10 for all
//! requests; E = (A: 4, B: 3, C: 5, D: 2).

use zoe::core::unit_request;
use zoe::policy::Policy;
use zoe::pool::Cluster;
use zoe::sched::SchedKind;
use zoe::sim::simulate;

fn main() {
    let requests = || {
        vec![
            unit_request(0, 0.0, 10.0, 3, 4), // A
            unit_request(1, 0.0, 10.0, 3, 3), // B
            unit_request(2, 0.0, 10.0, 3, 5), // C
            unit_request(3, 0.0, 10.0, 3, 2), // D
        ]
    };

    println!("Fig. 1 — illustrative example: R=10 units, 4 requests (C=3, T=10, E=4/3/5/2)\n");
    for kind in [SchedKind::Rigid, SchedKind::Malleable, SchedKind::Flexible] {
        let mut res = simulate(requests(), Cluster::units(10), Policy::FIFO, kind);
        println!(
            "{:<10}  avg turnaround = {:>6.2} s   (per-request: {:?})",
            kind.label(),
            res.turnaround.mean(),
            res.turnaround
                .values()
                .iter()
                .map(|x| (x * 100.0).round() / 100.0)
                .collect::<Vec<_>>()
        );
    }
    println!("\npaper: rigid 25 s, malleable 20 s, flexible 19.25 s");
    println!("(flexible reclaims one elastic unit from request C to start D's cores early)");
}

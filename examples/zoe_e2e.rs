//! End-to-end Zoe experiment (§6, Fig. 33): two generations of Zoe — the
//! rigid gen-1 baseline and the flexible gen-2 scheduler — replay the
//! *exact same* workload trace of real analytic applications on the
//! simulated 10-server Swarm back-end. Application containers execute
//! genuine compute (ALS / ridge / TF-style training steps through the
//! AOT-compiled PJRT artifacts), so the whole three-layer stack is on the
//! path: rust coordinator → HLO artifacts ← JAX+Pallas.
//!
//! Experiment time is a virtual clock under which application speed
//! scales with granted containers (see `zoe::zoe::replay`); every step is
//! still a real PJRT execution.
//!
//! ```sh
//! cargo run --release --example zoe_e2e -- --apps 100 --seed 7
//! ```

use std::sync::Arc;

use zoe::runtime::PjrtRuntime;
use zoe::sched::SchedSpec;
use zoe::util::cli::Args;
use zoe::zoe::{replay, section6_workload};

fn main() {
    zoe::util::logging::init();
    let args = Args::from_env();
    let apps = args.u64_or("apps", 100) as u32;
    let seed = args.u64_or("seed", 7);
    let gap_scale = args.f64_or("gap-scale", 12.0);
    let rate = args.f64_or("rate", 1.0);
    let quanta = args.usize_or("quanta", 64);

    let rt = Arc::new(match PjrtRuntime::load_default() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("cannot load artifacts ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    });
    println!("PJRT platform: {} | artifacts: {:?}", rt.platform(), rt.names());

    let arrivals = section6_workload(apps, seed, gap_scale);
    let n_elastic = arrivals.iter().filter(|a| a.elastic).count();
    println!(
        "workload: {} apps ({} elastic / {} rigid), submissions span {:.1} virtual s",
        arrivals.len(),
        n_elastic,
        arrivals.len() - n_elastic,
        arrivals.last().unwrap().at
    );

    // The Fig-33 pair: gen-1 (rigid) vs gen-2 (flexible). `replay` takes
    // any SchedSpec, so other generations / registered cores drop in.
    let mut specs: Vec<SchedSpec> = Vec::new();
    for name in ["rigid", "flexible"] {
        specs.push(name.parse().expect("built-in spec"));
    }
    let mut results = Vec::new();
    for spec in &specs {
        println!("\n=== running {} ===", spec.label());
        let r = replay(spec, &arrivals, Arc::clone(&rt), quanta, rate);
        println!(
            "  {} PJRT steps in {:.1}s wall → makespan {:.1} virtual s",
            r.steps, r.wall, r.vtime
        );
        results.push(r);
    }

    println!("\n================= Fig 33 (left): turnaround (virtual s) ==========");
    for r in &mut results {
        println!("{}:", r.label);
        println!("  B-E     {}", r.turnaround_be.boxplot());
        println!("  B-R     {}", r.turnaround_br.boxplot());
        println!("  queuing {}", r.queuing.boxplot());
    }
    println!("\n================= Fig 33 (right): allocation ratio ===============");
    for r in &mut results {
        println!("{}: cpu {}", r.label, r.alloc_cpu.boxplot());
    }
    println!("\n================= §6 ramp-up (container placement, ms) ===========");
    for r in &mut results {
        println!(
            "{}: mean {:.4} p50 {:.4} p95 {:.4} (paper: 0.90 ± 0.25 incl. Docker)",
            r.label,
            r.rampup_ms.mean(),
            r.rampup_ms.percentile(50.0),
            r.rampup_ms.percentile(95.0)
        );
    }

    let (rb, fb) = (
        results[0].turnaround_be.median(),
        results[1].turnaround_be.median(),
    );
    let (rr, fr) = (
        results[0].turnaround_br.median(),
        results[1].turnaround_br.median(),
    );
    let (ra, fa) = (results[0].alloc_cpu.median(), results[1].alloc_cpu.median());
    println!("\n================= headline (flexible / rigid) ====================");
    println!("median B-E turnaround ratio: {:.2} (paper ≈ 0.63)", fb / rb);
    println!("median B-R turnaround ratio: {:.2} (paper ≈ 0.78)", fr / rr);
    println!("median cpu allocation ratio: {:.2} (paper ≈ 1.20)", fa / ra.max(1e-9));
}

//! Ablation study (extends §4.4 "Impact of different workloads"):
//!
//! 1. **Elasticity sweep** — vary the fraction of batch applications that
//!    are elastic (B-E) from 0 % to 100 %. The paper argues the flexible
//!    scheduler's benefit grows with elasticity and collapses to the
//!    rigid baseline at 0 % (Table 3); this regenerates that whole curve.
//! 2. **Load sweep** — vary offered load via the arrival-scale knob.
//!    Flexible's advantage should widen as the system saturates (queuing
//!    dominates) and vanish when the cluster is empty.
//!
//! ```sh
//! cargo run --release --example ablation -- --apps 8000 --seeds 3
//! ```

use zoe::policy::Policy;
use zoe::sched::SchedKind;
use zoe::sim::run_many;
use zoe::util::cli::Args;
use zoe::workload::WorkloadSpec;

fn main() {
    let args = Args::from_env();
    let apps = args.u64_or("apps", 8000) as u32;
    let seeds = args.u64_or("seeds", 3);

    println!("=== ablation 1: elastic fraction sweep (FIFO, {apps} apps × {seeds} seeds) ===");
    println!(
        "  {:>9} {:>16} {:>16} {:>8} {:>12} {:>12}",
        "elastic%", "rigid med ta", "flex med ta", "ratio", "rigid alloc", "flex alloc"
    );
    for frac in [0.0, 0.25, 0.5, 0.8, 1.0] {
        let mut spec = WorkloadSpec::paper_batch_only();
        spec.batch_elastic_frac = frac;
        let mut rigid = run_many(&spec, apps, 1..seeds + 1, Policy::FIFO, SchedKind::Rigid);
        let mut flex = run_many(&spec, apps, 1..seeds + 1, Policy::FIFO, SchedKind::Flexible);
        let (r, f) = (rigid.turnaround.median(), flex.turnaround.median());
        println!(
            "  {:>8.0}% {:>15.1}s {:>15.1}s {:>8.2} {:>11.1}% {:>11.1}%",
            frac * 100.0,
            r,
            f,
            f / r,
            100.0 * rigid.cpu_alloc.boxplot().mean,
            100.0 * flex.cpu_alloc.boxplot().mean,
        );
    }
    println!("  (expected: ratio → 1 as elasticity → 0; improves with elasticity)");

    println!("\n=== ablation 2: load sweep (FIFO, arrival-scale knob) ===");
    println!(
        "  {:>9} {:>16} {:>16} {:>8}",
        "ia-scale", "rigid med ta", "flex med ta", "ratio"
    );
    for scale in [0.8, 1.0, 1.5, 2.5, 4.0] {
        let mut spec = WorkloadSpec::paper_batch_only();
        spec.arrival_scale = scale;
        let mut rigid = run_many(&spec, apps, 1..seeds + 1, Policy::FIFO, SchedKind::Rigid);
        let mut flex = run_many(&spec, apps, 1..seeds + 1, Policy::FIFO, SchedKind::Flexible);
        let (r, f) = (rigid.turnaround.median(), flex.turnaround.median());
        println!(
            "  {:>9.1} {:>15.1}s {:>15.1}s {:>8.2}",
            scale,
            r,
            f,
            f / r
        );
    }
    println!("  (expected: ratio → 1 as load → 0; widens under overload)");

    println!("\n=== ablation 3: admission aggressiveness (flexible vs malleable) ===");
    println!("  The flexible scheduler's only extra lever over malleable is core");
    println!("  admission by elastic reclaim; compare per-policy:");
    for (name, policy) in [
        ("FIFO", Policy::FIFO),
        ("SJF", Policy::sjf()),
        ("SRPT", Policy::srpt()),
    ] {
        let spec = WorkloadSpec::paper_batch_only();
        let mut mal = run_many(&spec, apps, 1..seeds + 1, policy, SchedKind::Malleable);
        let mut flex = run_many(&spec, apps, 1..seeds + 1, policy, SchedKind::Flexible);
        println!(
            "  {name:<5} malleable med {:>12.1}s mean {:>12.1}s | flexible med {:>12.1}s mean {:>12.1}s",
            mal.turnaround.median(),
            mal.turnaround.mean(),
            flex.turnaround.median(),
            flex.turnaround.mean(),
        );
    }
}

//! Ablation study (extends §4.4 "Impact of different workloads"):
//!
//! 1. **Elasticity sweep** — vary the fraction of batch applications that
//!    are elastic (B-E) from 0 % to 100 %. The paper argues the flexible
//!    scheduler's benefit grows with elasticity and collapses to the
//!    rigid baseline at 0 % (Table 3); this regenerates that whole curve.
//! 2. **Load sweep** — vary offered load via the arrival-scale knob.
//!    Flexible's advantage should widen as the system saturates (queuing
//!    dominates) and vanish when the cluster is empty.
//! 3. **Admission aggressiveness** — flexible vs malleable per policy.
//!
//! Every sweep point runs both schedulers over all seeds as one parallel
//! [`ExperimentPlan`] grid (`--threads` caps the workers).
//!
//! ```sh
//! cargo run --release --example ablation -- --apps 8000 --seeds 3
//! ```

use zoe::policy::Policy;
use zoe::sched::SchedKind;
use zoe::sim::{ExperimentPlan, SimResult};
use zoe::util::cli::Args;
use zoe::workload::WorkloadSpec;

/// Run `(rigid-ish, flexible-ish)` as one grid and return both merged.
fn pair(
    spec: &WorkloadSpec,
    apps: u32,
    seeds: u64,
    threads: usize,
    policy: Policy,
    a: SchedKind,
    b: SchedKind,
) -> (SimResult, SimResult) {
    let result = ExperimentPlan::new(spec.clone(), apps)
        .seeds(1..seeds + 1)
        .config(policy, a)
        .config(policy, b)
        .threads(threads)
        .run();
    (result.runs[0].merged(), result.runs[1].merged())
}

fn main() {
    let args = Args::from_env();
    let apps = args.u64_or("apps", 8000) as u32;
    let seeds = args.u64_or("seeds", 3);
    let threads = args.usize_or("threads", 0);

    println!("=== ablation 1: elastic fraction sweep (FIFO, {apps} apps × {seeds} seeds) ===");
    println!(
        "  {:>9} {:>16} {:>16} {:>8} {:>12} {:>12}",
        "elastic%", "rigid med ta", "flex med ta", "ratio", "rigid alloc", "flex alloc"
    );
    for frac in [0.0, 0.25, 0.5, 0.8, 1.0] {
        let mut spec = WorkloadSpec::paper_batch_only();
        spec.batch_elastic_frac = frac;
        let (mut rigid, mut flex) = pair(
            &spec,
            apps,
            seeds,
            threads,
            Policy::FIFO,
            SchedKind::Rigid,
            SchedKind::Flexible,
        );
        let (r, f) = (rigid.turnaround.median(), flex.turnaround.median());
        println!(
            "  {:>8.0}% {:>15.1}s {:>15.1}s {:>8.2} {:>11.1}% {:>11.1}%",
            frac * 100.0,
            r,
            f,
            f / r,
            100.0 * rigid.cpu_alloc.boxplot().mean,
            100.0 * flex.cpu_alloc.boxplot().mean,
        );
    }
    println!("  (expected: ratio → 1 as elasticity → 0; improves with elasticity)");

    println!("\n=== ablation 2: load sweep (FIFO, arrival-scale knob) ===");
    println!(
        "  {:>9} {:>16} {:>16} {:>8}",
        "ia-scale", "rigid med ta", "flex med ta", "ratio"
    );
    for scale in [0.8, 1.0, 1.5, 2.5, 4.0] {
        let mut spec = WorkloadSpec::paper_batch_only();
        spec.arrival_scale = scale;
        let (mut rigid, mut flex) = pair(
            &spec,
            apps,
            seeds,
            threads,
            Policy::FIFO,
            SchedKind::Rigid,
            SchedKind::Flexible,
        );
        let (r, f) = (rigid.turnaround.median(), flex.turnaround.median());
        println!(
            "  {:>9.1} {:>15.1}s {:>15.1}s {:>8.2}",
            scale,
            r,
            f,
            f / r
        );
    }
    println!("  (expected: ratio → 1 as load → 0; widens under overload)");

    println!("\n=== ablation 3: admission aggressiveness (flexible vs malleable) ===");
    println!("  The flexible scheduler's only extra lever over malleable is core");
    println!("  admission by elastic reclaim; compare per-policy:");
    for (name, policy) in [
        ("FIFO", Policy::FIFO),
        ("SJF", Policy::sjf()),
        ("SRPT", Policy::srpt()),
    ] {
        let spec = WorkloadSpec::paper_batch_only();
        let (mut mal, mut flex) = pair(
            &spec,
            apps,
            seeds,
            threads,
            policy,
            SchedKind::Malleable,
            SchedKind::Flexible,
        );
        println!(
            "  {name:<5} malleable med {:>12.1}s mean {:>12.1}s | flexible med {:>12.1}s mean {:>12.1}s",
            mal.turnaround.median(),
            mal.turnaround.mean(),
            flex.turnaround.median(),
            flex.turnaround.mean(),
        );
    }
}

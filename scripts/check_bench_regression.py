#!/usr/bin/env python3
"""Compare a fresh BENCH_sim_throughput.json against the committed baseline.

Usage: check_bench_regression.py BASELINE NEW [--threshold 0.20]

Fails (exit 1) when any (sched, mode, apps) point in NEW is more than
THRESHOLD slower (events/s) than the same point in BASELINE. Points
missing from either file are reported but not fatal (the sweep is
environment-capped via ZOE_BENCH_SWEEP_MAX). A baseline marked
"provisional": true records hardware-dependent numbers that were never
measured on CI hardware; in that case the script only prints the fresh
numbers and succeeds, so the first CI run on real hardware can promote
the fresh file to the new baseline.

Also checks the steady_state_memory point (request-slab high-water and
table capacity after the churn sweep): a table capacity above the slab
high-water mark is a structural slab leak and fails unconditionally
(hardware-independent); against a measured baseline at the same app
count, a high-water mark more than THRESHOLD above the baseline fails
too (the workload is seeded, so the active peak is deterministic).

The distributed_sweep point (loopback coordinator + socket workers) is
gated structurally as well: the bench run is crash-free, so non-zero
releases or duplicates mean the lease lifecycle dropped or double-
counted a healthy worker and fail even against a provisional baseline.
Its events/s rides the normal per-point threshold comparison via the
(flexible, distributed_sweep, apps) results entry.

The overload fast-path point is gated structurally too, even against a
provisional baseline: under 10x-capacity saturation the optimized
engine must be strictly faster than the naive wholesale-sort engine,
must record zero full sorts, and must record gated (prefilter-skipped)
events. Its per-policy optimized/naive events/s ride the normal
threshold comparison via the (flexible, overload_*, apps) results
entries.
"""

import json
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def key(point):
    return (point["sched"], point.get("mode", "optimized"), int(point["apps"]))


def report_parallel(doc, label):
    """Print the parallel_scaling table; returns (hw_threads, best speedup
    at >=4 threads) or (0, None) when absent."""
    ps = doc.get("parallel_scaling") or {}
    points = ps.get("points", [])
    if not points:
        print(f"{label}: no parallel_scaling points")
        return 0, None
    hw = int(ps.get("hw_threads", 0))
    print(f"{label}: parallel scaling ({ps.get('apps')} apps x {ps.get('seeds')} seeds, "
          f"{ps.get('sched')}, {hw} hw threads)")
    best4 = None
    for p in points:
        t = int(p["threads"])
        s = float(p.get("speedup_vs_1thread", 0.0))
        print(f"  threads={t:<2} wall={p.get('wall_s', 0.0):>9.3f}s speedup={s:5.2f}x")
        if t >= 4:
            best4 = s if best4 is None else max(best4, s)
    return hw, best4


def report_sweep(doc, label):
    """Print the distributed_sweep point; returns it (or None)."""
    s = doc.get("distributed_sweep") or {}
    if not s or not s.get("apps"):
        print(f"{label}: no distributed_sweep point")
        return None
    print(f"{label}: distributed sweep ({int(s['apps'])} apps x {int(s.get('seeds', 0))} seeds "
          f"over {int(s.get('workers', 0))} workers): "
          f"{float(s.get('events_per_s', 0.0)):.0f} events/s, "
          f"releases={int(s.get('releases', 0))}, duplicates={int(s.get('duplicates', 0))}")
    return s


def report_decision_cache(doc, label):
    """Print the decision_cache point; returns it (or None)."""
    c = doc.get("decision_cache") or {}
    if not c or not c.get("apps"):
        print(f"{label}: no decision_cache point")
        return None
    print(f"{label}: decision cache @ {int(c['apps'])} apps ({c.get('sched')}): "
          f"bare {float(c.get('bare_events_per_s', 0.0)):.0f} -> "
          f"cached {float(c.get('cached_events_per_s', 0.0)):.0f} events/s "
          f"({float(c.get('speedup', 0.0)):.2f}x), "
          f"hit rate {float(c.get('hit_rate', 0.0)):.1%}, "
          f"hits={int(c.get('hits', 0))} misses={int(c.get('misses', 0))} "
          f"validation_failures={int(c.get('validation_failures', 0))}")
    return c


def report_slo(doc, label):
    """Print the slo_attainment point; returns it (or None)."""
    s = doc.get("slo_attainment") or {}
    if not s or not s.get("apps"):
        print(f"{label}: no slo_attainment point")
        return None
    bare_total = int(s.get("bare_met", 0)) + int(s.get("bare_missed", 0))
    slo_total = int(s.get("slo_met", 0)) + int(s.get("slo_missed", 0))
    print(f"{label}: SLO attainment @ {int(s['apps'])} apps "
          f"(deadline_frac={float(s.get('deadline_frac', 0.0))}): "
          f"{s.get('bare_sched')}+{s.get('bare_policy')} met {int(s.get('bare_met', 0))}/{bare_total} -> "
          f"{s.get('slo_sched')}+{s.get('slo_policy')} met {int(s.get('slo_met', 0))}/{slo_total} "
          f"(rejections={int(s.get('rejections', 0))}, "
          f"reclaim_saves={int(s.get('reclaim_saves', 0))})")
    return s


def report_overload(doc, label):
    """Print the overload fast-path point; returns it (or None)."""
    o = doc.get("overload") or {}
    if not o or not o.get("apps"):
        print(f"{label}: no overload point")
        return None
    print(f"{label}: overload fast path @ {int(o['apps'])} apps "
          f"({o.get('sched')}, arrival_scale={float(o.get('arrival_scale', 0.0))})")
    for p in o.get("points", []):
        print(f"  {p.get('policy'):<5} optimized {float(p.get('optimized_events_per_s', 0.0)):>12.0f} "
              f"vs naive {float(p.get('naive_events_per_s', 0.0)):>12.0f} events/s "
              f"({float(p.get('speedup', 0.0)):5.2f}x), "
              f"queue high-water {int(p.get('queue_depth_high_water', 0))}, "
              f"gated={int(p.get('gated_events', 0))}, "
              f"full_sorts opt={int(p.get('optimized_full_sorts', 0))} "
              f"naive={int(p.get('naive_full_sorts', 0))}")
    return o


def report_memory(doc, label):
    """Print the steady_state_memory point; returns it (or None)."""
    m = doc.get("steady_state_memory") or {}
    if not m or not m.get("apps"):
        print(f"{label}: no steady_state_memory point")
        return None
    print(f"{label}: steady-state memory @ {int(m['apps'])} apps: "
          f"slab high-water {int(m.get('slab_high_water', 0))}, "
          f"table capacity {int(m.get('table_capacity', 0))}")
    return m


def main():
    argv = sys.argv[1:]
    args, threshold = [], 0.20
    i = 0
    while i < len(argv):
        a = argv[i]
        if a == "--threshold":
            i += 1
            threshold = float(argv[i])
        elif a.startswith("--threshold="):
            threshold = float(a.split("=", 1)[1])
        else:
            args.append(a)
        i += 1
    if len(args) != 2:
        print(__doc__)
        return 2
    baseline, new = load(args[0]), load(args[1])

    new_points = {key(p): p for p in new.get("results", [])}
    print(f"fresh bench points: {len(new_points)}")
    for k, p in sorted(new_points.items()):
        print(f"  {k[0]:<10} {k[1]:<9} apps={k[2]:<7} {p['events_per_s']:>12.0f} events/s")

    hw, best4 = report_parallel(new, "fresh")
    new_mem = report_memory(new, "fresh")
    new_sweep = report_sweep(new, "fresh")
    new_cache = report_decision_cache(new, "fresh")
    new_slo = report_slo(new, "fresh")
    new_overload = report_overload(new, "fresh")

    # Structural slab invariant, hardware-independent: the request table
    # must never outgrow the active high-water mark. Checked even against
    # a provisional baseline.
    mem_failures = []
    if new_mem and int(new_mem.get("table_capacity", 0)) > int(new_mem.get("slab_high_water", 0)):
        print(f"FAIL: table capacity {new_mem['table_capacity']} exceeds slab "
              f"high-water {new_mem['slab_high_water']} (slab leak)")
        mem_failures.append(("memory", "capacity>high_water"))

    # Distributed-sweep correctness ledger, hardware-independent: the
    # bench's loopback run is crash-free, so any re-lease or duplicate
    # there means the coordinator dropped or double-counted a healthy
    # worker's lease. Checked even against a provisional baseline.
    if new_sweep and (int(new_sweep.get("releases", 0)) > 0 or
                      int(new_sweep.get("duplicates", 0)) > 0):
        print(f"FAIL: crash-free distributed sweep recorded "
              f"releases={new_sweep.get('releases')} duplicates={new_sweep.get('duplicates')} "
              f"(lease lifecycle bug)")
        mem_failures.append(("distributed_sweep", "releases/duplicates on clean run"))

    # Decision-cache structural invariants, hardware-independent: the
    # bench workload is one repeated template on a churn-free cluster, so
    # a cache that fails validation more often than it misses has a
    # broken occupancy key (entries match, state doesn't), and a zero hit
    # count means captures or replays stopped working. Checked even
    # against a provisional baseline.
    if new_cache:
        if int(new_cache.get("validation_failures", 0)) > int(new_cache.get("misses", 0)):
            print(f"FAIL: crash-free decision-cache bench recorded "
                  f"validation_failures={new_cache.get('validation_failures')} > "
                  f"misses={new_cache.get('misses')} (stale-prone cache key)")
            mem_failures.append(("decision_cache", "validation_failures > misses"))
        if int(new_cache.get("hits", 0)) <= 0:
            print("FAIL: decision-cache bench recorded zero hits on the "
                  "repeat-template workload (capture/replay path dead)")
            mem_failures.append(("decision_cache", "zero hits"))

    # SLO-attainment structural invariant, hardware-independent: the
    # bench's head-to-head is deterministic (seeded workload, seeded
    # churn), so the deadline-aware stack failing to strictly beat
    # arrival order on deadlines met means the subsystem regressed.
    # Checked even against a provisional baseline.
    if new_slo and int(new_slo.get("slo_met", 0)) <= int(new_slo.get("bare_met", 0)):
        print(f"FAIL: SLO stack met {new_slo.get('slo_met')} deadlines vs bare "
              f"{new_slo.get('bare_met')} — the deadline-aware scheduler must "
              f"strictly improve attainment on the bench workload")
        mem_failures.append(("slo_attainment", "slo_met <= bare_met"))

    # Overload fast-path structural invariants, hardware-independent:
    # both engines run the same seeded workload on the same host, so the
    # saturation-gated selection engine being no faster than the
    # wholesale-sort engine means the fast path stopped engaging; a
    # non-zero optimized full-sort count means the selection path fell
    # back to sorting; zero gated events under 10x overload means the
    # admissibility prefilter never fired. Checked even against a
    # provisional baseline.
    if new_overload:
        for p in new_overload.get("points", []):
            pol = p.get("policy", "?")
            opt = float(p.get("optimized_events_per_s", 0.0))
            naive = float(p.get("naive_events_per_s", 0.0))
            if opt <= naive:
                print(f"FAIL: overload {pol}: optimized {opt:.0f} events/s <= naive "
                      f"{naive:.0f} events/s — the fast path must beat the wholesale sort "
                      f"in the saturated regime")
                mem_failures.append(("overload", f"{pol}: optimized <= naive"))
            if int(p.get("optimized_full_sorts", 0)) > 0:
                print(f"FAIL: overload {pol}: optimized engine recorded "
                      f"{p.get('optimized_full_sorts')} full sorts (selection path fell back)")
                mem_failures.append(("overload", f"{pol}: optimized full_sorts > 0"))
            if int(p.get("gated_events", 0)) <= 0:
                print(f"FAIL: overload {pol}: zero gated events under sustained overload "
                      f"(admissibility prefilter never engaged)")
                mem_failures.append(("overload", f"{pol}: zero gated events"))

    if baseline.get("provisional"):
        print("baseline is provisional (no measured numbers committed); "
              "recording only — promote the fresh file to the baseline.")
        return 1 if mem_failures else 0

    base_points = {key(p): p for p in baseline.get("results", [])}
    failures = []
    # With a measured baseline, the parallel-scaling target is enforced:
    # the 10-seed paper workload must reach >=3x at 4+ threads. Only
    # enforced when the host has >=6 hardware threads: with exactly 4
    # workers the 10-task grid needs 3 rounds, capping the theoretical
    # speedup at 3.33x, which leaves no headroom for runner noise — on
    # such hosts the table is reported but not gated. Collected alongside
    # the per-point comparisons so a single run reports every failure.
    failures.extend((k, 0, 0) for k in mem_failures)
    if hw >= 6 and best4 is not None and best4 < 3.0:
        print(f"FAIL: parallel speedup at 4+ threads is {best4:.2f}x (< 3.0x target)")
        failures.append((("parallel", "speedup", 4), 3.0, best4))
    # Slab high-water regression: deterministic (seeded workload), so a
    # growth beyond the threshold means the engine holds requests live
    # longer than it used to (or stopped recycling).
    base_mem = baseline.get("steady_state_memory") or {}
    if (new_mem and base_mem.get("apps") and
            int(base_mem["apps"]) == int(new_mem["apps"]) and
            float(base_mem.get("slab_high_water", 0)) > 0):
        old_hw = float(base_mem["slab_high_water"])
        cur_hw = float(new_mem["slab_high_water"])
        ratio = cur_hw / old_hw
        status = "ok" if ratio <= 1.0 + threshold else "REGRESSION"
        print(f"  slab high-water @ {int(new_mem['apps'])} apps: "
              f"{old_hw:.0f} -> {cur_hw:.0f} ({ratio:5.2f}x) {status}")
        if ratio > 1.0 + threshold:
            failures.append((("memory", "slab_high_water", int(new_mem["apps"])), old_hw, cur_hw))
    # Decision-cache throughput regression: the cached events/s at the
    # same app count rides the same threshold as the per-point table.
    base_cache = baseline.get("decision_cache") or {}
    if (new_cache and base_cache.get("apps") and
            int(base_cache["apps"]) == int(new_cache["apps"]) and
            float(base_cache.get("cached_events_per_s", 0)) > 0):
        old_eps = float(base_cache["cached_events_per_s"])
        cur_eps = float(new_cache["cached_events_per_s"])
        ratio = cur_eps / old_eps
        status = "ok" if ratio >= 1.0 - threshold else "REGRESSION"
        print(f"  decision cache @ {int(new_cache['apps'])} apps: "
              f"{old_eps:.0f} -> {cur_eps:.0f} events/s ({ratio:5.2f}x) {status}")
        if ratio < 1.0 - threshold:
            failures.append((("decision_cache", "cached_events_per_s",
                              int(new_cache["apps"])), old_eps, cur_eps))
    # SLO-stack throughput regression: the deadline-aware wrapper's
    # events/s at the same app count rides the same threshold — the
    # laxity scan must stay O(changed), not O(running).
    base_slo = baseline.get("slo_attainment") or {}
    if (new_slo and base_slo.get("apps") and
            int(base_slo["apps"]) == int(new_slo["apps"]) and
            float(base_slo.get("slo_events_per_s", 0)) > 0):
        old_eps = float(base_slo["slo_events_per_s"])
        cur_eps = float(new_slo["slo_events_per_s"])
        ratio = cur_eps / old_eps
        status = "ok" if ratio >= 1.0 - threshold else "REGRESSION"
        print(f"  slo stack @ {int(new_slo['apps'])} apps: "
              f"{old_eps:.0f} -> {cur_eps:.0f} events/s ({ratio:5.2f}x) {status}")
        if ratio < 1.0 - threshold:
            failures.append((("slo_attainment", "slo_events_per_s",
                              int(new_slo["apps"])), old_eps, cur_eps))
    for k, bp in sorted(base_points.items()):
        np_ = new_points.get(k)
        if np_ is None:
            print(f"  NOTE missing point in fresh run: {k}")
            continue
        old, cur = bp["events_per_s"], np_["events_per_s"]
        if old <= 0:
            continue
        ratio = cur / old
        status = "ok" if ratio >= 1.0 - threshold else "REGRESSION"
        print(f"  {k[0]:<10} {k[1]:<9} apps={k[2]:<7} {old:>12.0f} -> {cur:>12.0f} "
              f"({ratio:5.2f}x) {status}")
        if ratio < 1.0 - threshold:
            failures.append((k, old, cur))

    if failures:
        print(f"FAIL: {len(failures)} point(s) regressed more than {threshold:.0%}")
        return 1
    print("throughput within threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())

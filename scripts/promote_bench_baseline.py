#!/usr/bin/env python3
"""Promote a freshly measured perf_microbench output to the committed baseline.

Usage: promote_bench_baseline.py [NEW] [--baseline PATH] [--force]

NEW defaults to bench_fresh.json (what CI writes via ZOE_BENCH_OUT);
--baseline defaults to BENCH_sim_throughput.json. The committed baseline
has been `"provisional": true` since PR 1 (no Rust toolchain existed in
the authoring environments), so the regression gate in
check_bench_regression.py runs record-only. This script closes that
loop: run `cargo bench --bench perf_microbench` once on real hardware,
then promote its output in one command —

    ZOE_BENCH_OUT=bench_fresh.json cargo bench --bench perf_microbench
    python3 scripts/promote_bench_baseline.py bench_fresh.json

The script validates the fresh file (non-empty results, positive
throughputs, a parallel_scaling table), clears the provisional flag,
and writes it over the baseline. A baseline that is already measured
(provisional absent/false) is protected: pass --force to replace it.
Commit the updated baseline to arm the CI gate.
"""

import json
import sys


def fail(msg):
    print(f"ERROR: {msg}")
    return 1


def main():
    argv = sys.argv[1:]
    new_path, baseline_path, force = "bench_fresh.json", "BENCH_sim_throughput.json", False
    i = 0
    positional = []
    while i < len(argv):
        a = argv[i]
        if a == "--baseline":
            i += 1
            baseline_path = argv[i]
        elif a.startswith("--baseline="):
            baseline_path = a.split("=", 1)[1]
        elif a == "--force":
            force = True
        elif a in ("-h", "--help"):
            print(__doc__)
            return 0
        else:
            positional.append(a)
        i += 1
    if len(positional) > 1:
        print(__doc__)
        return 2
    if positional:
        new_path = positional[0]

    try:
        with open(new_path) as f:
            new = json.load(f)
    except (OSError, ValueError) as e:
        return fail(f"cannot read fresh bench file {new_path}: {e}")

    # --- validate the fresh run looks like a real measurement ------------
    results = new.get("results", [])
    if not results:
        return fail(f"{new_path} has no measured results[] — was the bench interrupted?")
    for p in results:
        for key in ("sched", "apps", "events_per_s"):
            if key not in p:
                return fail(f"{new_path}: result point missing '{key}': {p}")
        if float(p["events_per_s"]) <= 0:
            return fail(f"{new_path}: non-positive throughput in {p}")
    ps = new.get("parallel_scaling") or {}
    if not ps.get("points"):
        return fail(f"{new_path} has no parallel_scaling points — rerun the full bench")
    mem = new.get("steady_state_memory") or {}
    if not mem.get("apps"):
        return fail(f"{new_path} has no steady_state_memory point — rerun the full bench")
    if int(mem.get("table_capacity", 0)) > int(mem.get("slab_high_water", 0)):
        return fail(f"{new_path}: table capacity {mem['table_capacity']} exceeds slab "
                    f"high-water {mem['slab_high_water']} — a slab leak is not a baseline")
    sweep = new.get("distributed_sweep") or {}
    if not sweep.get("apps"):
        return fail(f"{new_path} has no distributed_sweep point — rerun the full bench "
                    "(ZOE_BENCH_SWEEP_MAX must be > 0)")
    if float(sweep.get("events_per_s", 0)) <= 0:
        return fail(f"{new_path}: non-positive distributed_sweep throughput: {sweep}")
    if int(sweep.get("releases", 0)) > 0 or int(sweep.get("duplicates", 0)) > 0:
        return fail(f"{new_path}: crash-free distributed sweep recorded releases="
                    f"{sweep.get('releases')} duplicates={sweep.get('duplicates')} — "
                    "a lease-lifecycle bug is not a baseline")
    cache = new.get("decision_cache") or {}
    if not cache.get("apps"):
        return fail(f"{new_path} has no decision_cache point — rerun the full bench "
                    "(ZOE_BENCH_SWEEP_MAX must be > 0)")
    if float(cache.get("cached_events_per_s", 0)) <= 0:
        return fail(f"{new_path}: non-positive decision-cache throughput: {cache}")
    if int(cache.get("hits", 0)) <= 0:
        return fail(f"{new_path}: decision-cache bench recorded zero hits on the "
                    "repeat-template workload — a dead cache is not a baseline")
    if int(cache.get("validation_failures", 0)) > int(cache.get("misses", 0)):
        return fail(f"{new_path}: decision cache failed validation more often than it "
                    f"missed (validation_failures={cache.get('validation_failures')} > "
                    f"misses={cache.get('misses')}) — a stale-prone key is not a baseline")

    slo = new.get("slo_attainment") or {}
    if not slo.get("apps"):
        return fail(f"{new_path} has no slo_attainment point — rerun the full bench "
                    "(ZOE_BENCH_SWEEP_MAX must be > 0)")
    if float(slo.get("slo_events_per_s", 0)) <= 0:
        return fail(f"{new_path}: non-positive SLO-stack throughput: {slo}")
    if int(slo.get("slo_met", 0)) <= int(slo.get("bare_met", 0)):
        return fail(f"{new_path}: SLO stack met {slo.get('slo_met')} deadlines vs bare "
                    f"{slo.get('bare_met')} — a deadline scheduler that does not beat "
                    "arrival order is not a baseline")

    overload = new.get("overload") or {}
    if not overload.get("apps") or not overload.get("points"):
        return fail(f"{new_path} has no overload point — rerun the full bench "
                    "(ZOE_BENCH_SWEEP_MAX must be > 0)")
    for p in overload.get("points", []):
        pol = p.get("policy", "?")
        opt = float(p.get("optimized_events_per_s", 0))
        naive = float(p.get("naive_events_per_s", 0))
        if opt <= 0 or naive <= 0:
            return fail(f"{new_path}: non-positive overload throughput for {pol}: {p}")
        if opt <= naive:
            return fail(f"{new_path}: overload {pol}: optimized {opt:.0f} events/s does not "
                        f"beat naive {naive:.0f} — a fast path that loses to the wholesale "
                        "sort is not a baseline")
        if int(p.get("optimized_full_sorts", 0)) > 0:
            return fail(f"{new_path}: overload {pol}: optimized engine full-sorted "
                        f"{p.get('optimized_full_sorts')} times — the selection path "
                        "fell back to sorting")

    if new_path != baseline_path:
        try:
            with open(baseline_path) as f:
                baseline = json.load(f)
        except OSError:
            baseline = None
        if baseline is not None and not baseline.get("provisional") and not force:
            return fail(
                f"{baseline_path} is already a measured baseline; "
                "pass --force to replace it"
            )

    new["provisional"] = False
    new.pop("note", None)
    with open(baseline_path, "w") as f:
        json.dump(new, f, indent=2, sort_keys=False)
        f.write("\n")

    n_speedups = len(new.get("speedups", []))
    print(f"promoted {new_path} -> {baseline_path}:")
    print(f"  {len(results)} throughput points, {n_speedups} optimized-vs-naive speedups, "
          f"{len(ps.get('points', []))} parallel-scaling points "
          f"({int(ps.get('hw_threads', 0))} hw threads)")
    print(f"  steady-state memory @ {int(mem['apps'])} apps: slab high-water "
          f"{int(mem['slab_high_water'])}, table capacity {int(mem['table_capacity'])}")
    print(f"  distributed sweep: {float(sweep.get('events_per_s', 0.0)):.0f} events/s over "
          f"{int(sweep.get('workers', 0))} workers (releases={int(sweep.get('releases', 0))}, "
          f"duplicates={int(sweep.get('duplicates', 0))})")
    print(f"  decision cache @ {int(cache['apps'])} apps: "
          f"{float(cache.get('cached_events_per_s', 0.0)):.0f} events/s cached vs "
          f"{float(cache.get('bare_events_per_s', 0.0)):.0f} bare "
          f"({float(cache.get('speedup', 0.0)):.2f}x, hit rate "
          f"{float(cache.get('hit_rate', 0.0)):.1%})")
    print(f"  SLO attainment @ {int(slo['apps'])} apps: "
          f"{int(slo.get('slo_met', 0))} met ({slo.get('slo_sched')}+{slo.get('slo_policy')}) vs "
          f"{int(slo.get('bare_met', 0))} met ({slo.get('bare_sched')}+{slo.get('bare_policy')}), "
          f"rejections={int(slo.get('rejections', 0))}, "
          f"reclaim_saves={int(slo.get('reclaim_saves', 0))}")
    for p in overload.get("points", []):
        print(f"  overload {p.get('policy')} @ {int(overload['apps'])} apps: "
              f"{float(p.get('optimized_events_per_s', 0.0)):.0f} events/s optimized vs "
              f"{float(p.get('naive_events_per_s', 0.0)):.0f} naive "
              f"({float(p.get('speedup', 0.0)):.2f}x), queue high-water "
              f"{int(p.get('queue_depth_high_water', 0))}")
    print("commit the updated baseline to arm the CI regression gate "
          "(check_bench_regression.py now enforces thresholds).")
    return 0


if __name__ == "__main__":
    sys.exit(main())
